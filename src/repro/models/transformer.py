"""Config-driven model assembly for all ten assigned architectures.

Layers execute under a `lax.scan` over *periods* (one period = the repeating
kind pattern, e.g. ``[rglru, rglru, attn]`` for recurrentgemma or
``[attn]*4 + [xattn]`` for llama-vision); a remainder shorter than one
period is unrolled.  Scan keeps the HLO size O(1) in depth — essential for
the 80-compile dry-run matrix.

Execution paths (attention/MLP) are selected by the CelloPlan — the lowered
form of the schedule/buffer co-design (see core.policy).  Remat wrapping
happens in launch.train using the plan's checkpoint policy; the models tag
intermediates with `checkpoint_name` so the policy can grip them.

Modes:
  forward(..., mode="train"|"prefill") — full-sequence; prefill also
    returns the filled per-layer cache/state.
  decode_step(...) — one token against the cache (ring-buffered when the
    architecture uses a bounded attention window).
"""
from __future__ import annotations

import dataclasses
import math
from typing import Any, Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp

from ..configs.base import ArchConfig
from ..core.policy import CelloPlan
from .attention import (chunked_flash_attention,
                        naive_attention, pallas_attention)
from .common import (COMPUTE_DTYPE, PARAM_DTYPE, activation_fn, apply_rope,
                     constrain, is_gated, rms_norm, tag)
from .moe import apply_moe, init_moe_params, moe_pspecs
from .recurrent import (apply_rglru_seq, apply_rglru_step, apply_rwkv_seq,
                        apply_rwkv_step, init_rglru_params, init_rwkv_params,
                        rglru_pspecs, rwkv_pspecs)

PyTree = Any


# ---------------------------------------------------------------------------
# period decomposition
# ---------------------------------------------------------------------------

def period_structure(cfg: ArchConfig) -> Tuple[List[str], int, List[str]]:
    """(period_kinds, n_periods, remainder_kinds)."""
    kinds = cfg.layer_kinds()
    if cfg.hybrid_period:
        plen = cfg.hybrid_period
    elif cfg.cross_attn_every:
        plen = cfg.cross_attn_every
    else:
        plen = 1
    n_periods = len(kinds) // plen
    return kinds[:plen], n_periods, kinds[n_periods * plen:]


# ---------------------------------------------------------------------------
# parameter init + partition specs
# ---------------------------------------------------------------------------

def _dense(key, shape, scale, dtype):
    return (jax.random.normal(key, shape) * scale).astype(dtype)


def init_block_params(key, cfg: ArchConfig, kind: str,
                      dtype=PARAM_DTYPE) -> Dict[str, PyTree]:
    D, H, KVH, E = (cfg.d_model, cfg.n_heads, cfg.n_kv_heads,
                    cfg.resolved_head_dim)
    keys = jax.random.split(key, 8)
    p: Dict[str, PyTree] = {
        "ln1": jnp.zeros((D,), dtype),
        "ln2": jnp.zeros((D,), dtype),
    }
    s = D ** -0.5
    if kind in ("attn", "xattn"):
        p["attn"] = {
            "wq": _dense(keys[0], (D, H * E), s, dtype),
            "wk": _dense(keys[1], (D, KVH * E), s, dtype),
            "wv": _dense(keys[2], (D, KVH * E), s, dtype),
            "wo": _dense(keys[3], (H * E, D), (H * E) ** -0.5, dtype),
        }
    elif kind == "rglru":
        p["rglru"] = init_rglru_params(keys[0], D, dtype)
    elif kind == "rwkv":
        p["rwkv"] = init_rwkv_params(keys[0], D, cfg.n_heads, dtype)
    else:
        raise ValueError(kind)

    if cfg.is_moe:
        p["moe"] = init_moe_params(keys[4], D, cfg.d_ff, cfg.n_experts,
                                   cfg.activation, dtype)
    else:
        F = cfg.d_ff
        p["mlp"] = {"w_up": _dense(keys[5], (D, F), s, dtype),
                    "w_down": _dense(keys[6], (F, D), F ** -0.5, dtype)}
        if is_gated(cfg.activation):
            p["mlp"]["w_gate"] = _dense(keys[7], (D, F), s, dtype)
    return p


def block_pspecs(cfg: ArchConfig, kind: str) -> Dict[str, PyTree]:
    p: Dict[str, PyTree] = {"ln1": (None,), "ln2": (None,)}
    if kind in ("attn", "xattn"):
        p["attn"] = {"wq": (None, "model"), "wk": (None, "model"),
                     "wv": (None, "model"), "wo": ("model", None)}
    elif kind == "rglru":
        p["rglru"] = rglru_pspecs()
    elif kind == "rwkv":
        p["rwkv"] = rwkv_pspecs()
    if cfg.is_moe:
        p["moe"] = moe_pspecs(cfg.activation)
    else:
        p["mlp"] = {"w_up": (None, "model"), "w_down": ("model", None)}
        if is_gated(cfg.activation):
            p["mlp"]["w_gate"] = (None, "model")
    return p


def init_params(key, cfg: ArchConfig, dtype=PARAM_DTYPE) -> Dict[str, PyTree]:
    period, n_periods, rest = period_structure(cfg)
    k_embed, k_head, k_layers = jax.random.split(key, 3)
    params: Dict[str, PyTree] = {
        "embed": _dense(k_embed, (cfg.padded_vocab, cfg.d_model),
                        cfg.d_model ** -0.5, dtype),
        "final_norm": jnp.zeros((cfg.d_model,), dtype),
        "lm_head": _dense(k_head, (cfg.d_model, cfg.padded_vocab),
                          cfg.d_model ** -0.5, dtype),
    }
    lkeys = jax.random.split(k_layers, cfg.n_layers)
    periods: Dict[str, PyTree] = {}
    for s, kind in enumerate(period):
        stack = [init_block_params(lkeys[p_ * len(period) + s], cfg, kind,
                                   dtype)
                 for p_ in range(n_periods)]
        periods[f"slot{s}"] = jax.tree.map(lambda *xs: jnp.stack(xs), *stack)
    params["periods"] = periods
    params["rest"] = [
        init_block_params(lkeys[n_periods * len(period) + i], cfg, kind,
                          dtype)
        for i, kind in enumerate(rest)]
    return params


def param_pspecs(cfg: ArchConfig) -> Dict[str, PyTree]:
    """Logical PartitionSpec tree matching init_params structure."""
    period, n_periods, rest = period_structure(cfg)

    def lift(tree):   # stacked period params get a leading (replicated) axis
        return jax.tree.map(lambda spec: (None,) + tuple(spec), tree,
                            is_leaf=lambda x: isinstance(x, tuple))

    specs: Dict[str, PyTree] = {
        "embed": ("model", None),
        "final_norm": (None,),
        "lm_head": (None, "model"),
        "periods": {f"slot{s}": lift(block_pspecs(cfg, kind))
                    for s, kind in enumerate(period)},
        "rest": [block_pspecs(cfg, kind) for kind in rest],
    }
    return specs


# ---------------------------------------------------------------------------
# block application — full sequence
# ---------------------------------------------------------------------------

def _attend(p_attn, x, *, cfg: ArchConfig, plan: CelloPlan, causal: bool,
            img: Optional[jnp.ndarray], rope: bool,
            positions: Optional[jnp.ndarray],
            unroll: bool = False) -> Tuple[jnp.ndarray, Tuple]:
    B, S, D = x.shape
    H, KVH, E = cfg.n_heads, cfg.n_kv_heads, cfg.resolved_head_dim
    xc = x.astype(COMPUTE_DTYPE)
    q = (xc @ p_attn["wq"].astype(COMPUTE_DTYPE)).reshape(B, S, H, E)
    src = xc if img is None else img.astype(COMPUTE_DTYPE)
    T = src.shape[1]
    k = (src @ p_attn["wk"].astype(COMPUTE_DTYPE)).reshape(B, T, KVH, E)
    v = (src @ p_attn["wv"].astype(COMPUTE_DTYPE)).reshape(B, T, KVH, E)
    if rope and img is None:
        pos = positions if positions is not None else jnp.arange(S)
        q = apply_rope(q, pos, cfg.rope_theta)
        k = apply_rope(k, pos, cfg.rope_theta)
    q = tag(constrain(q, "batch", None, "model", None), "q_out")
    k = constrain(k, "batch", None, "model" if KVH > 1 else None, None)
    v = constrain(v, "batch", None, "model" if KVH > 1 else None, None)
    window = cfg.window if img is None else None
    if plan.use_flash_attention:
        if jax.default_backend() == "tpu":
            ctx = pallas_attention(q, k, v, causal=causal, window=window,
                                   q_block=plan.q_block,
                                   kv_block=plan.kv_block)
        else:
            ctx = chunked_flash_attention(q, k, v, causal=causal,
                                          window=window,
                                          kv_block=plan.kv_block,
                                          unroll=unroll)
    else:
        ctx = naive_attention(q, k, v, causal=causal, window=window)
    out = (ctx.reshape(B, S, H * E).astype(COMPUTE_DTYPE)
           @ p_attn["wo"].astype(COMPUTE_DTYPE))
    return tag(out, "attn_out").astype(x.dtype), (k, v)


def _mlp(p, x, cfg: ArchConfig, plan: CelloPlan) -> jnp.ndarray:
    B, S, D = x.shape
    flat = x.reshape(B * S, D)
    if cfg.is_moe:
        out = apply_moe(p["moe"], flat, top_k=cfg.top_k,
                        activation=cfg.activation,
                        capacity_factor=plan.moe_capacity_factor)
        return tag(out.reshape(B, S, D), "mlp_out")
    m = p["mlp"]
    gated = is_gated(cfg.activation)
    act_name = {"swiglu": "silu", "geglu": "gelu", "relu2": "relu2",
                "gelu": "gelu"}[cfg.activation]
    if plan.use_fused_mlp and jax.default_backend() == "tpu":
        from ..kernels.fused_mlp import fused_mlp
        out = fused_mlp(flat.astype(COMPUTE_DTYPE),
                        m.get("w_gate"), m["w_up"], m["w_down"],
                        activation=act_name, m_block=plan.mlp_block_m,
                        f_block=plan.mlp_block_f)
    else:
        xc = flat.astype(COMPUTE_DTYPE)
        act = activation_fn(cfg.activation)
        up = xc @ m["w_up"].astype(COMPUTE_DTYPE)
        up = constrain(up, "batch", "model")
        if gated:
            g = xc @ m["w_gate"].astype(COMPUTE_DTYPE)
            g = constrain(g, "batch", "model")
            h = act(g.astype(jnp.float32)).astype(COMPUTE_DTYPE) * up
        else:
            h = act(up.astype(jnp.float32)).astype(COMPUTE_DTYPE)
        h = tag(h, "mlp_hidden")
        out = h @ m["w_down"].astype(COMPUTE_DTYPE)
    return tag(out.reshape(B, S, D).astype(x.dtype), "mlp_out")


def apply_block(p, x, kind: str, *, cfg: ArchConfig, plan: CelloPlan,
                img: Optional[jnp.ndarray] = None,
                positions: Optional[jnp.ndarray] = None,
                unroll: bool = False) -> Tuple[jnp.ndarray, PyTree]:
    """Full-sequence block. Returns (x_out, cache_entry)."""
    h = rms_norm(x, p["ln1"], cfg.norm_eps)
    if kind in ("attn", "xattn"):
        causal = (not cfg.encoder_only) and kind == "attn"
        y, kv = _attend(p["attn"], h, cfg=cfg, plan=plan, causal=causal,
                        img=img if kind == "xattn" else None,
                        rope=not cfg.encoder_only, positions=positions,
                        unroll=unroll)
        cache_entry = kv
    elif kind == "rglru":
        y, hT = apply_rglru_seq(p["rglru"], h)
        cache_entry = hT
    elif kind == "rwkv":
        y, sT = apply_rwkv_seq(p["rwkv"], h, cfg.n_heads)
        cache_entry = sT
    else:
        raise ValueError(kind)
    x = tag(x + y, "x_mid")
    h2 = rms_norm(x, p["ln2"], cfg.norm_eps)
    x = x + _mlp(p, h2, cfg, plan)
    x = constrain(x, "batch", None, None)
    return x, cache_entry


# ---------------------------------------------------------------------------
# full forward
# ---------------------------------------------------------------------------

def embed_tokens(params, cfg: ArchConfig, tokens: jnp.ndarray) -> jnp.ndarray:
    emb = params["embed"].astype(COMPUTE_DTYPE)
    x = emb[tokens] * math.sqrt(cfg.d_model)
    return constrain(x, "batch", None, None)


def forward(params, cfg: ArchConfig, plan: CelloPlan, tokens: jnp.ndarray, *,
            frames: Optional[jnp.ndarray] = None,
            img: Optional[jnp.ndarray] = None,
            mode: str = "train",
            remat_policy=None,
            unroll: bool = False) -> Tuple[jnp.ndarray, PyTree]:
    """Full-sequence forward.

    tokens: (B, S) int32 (ignored for audio when ``frames`` given);
    frames:  (B, S, D) stubbed frame embeddings (audio);
    img:     (B, V, D) stubbed patch embeddings (vlm).
    unroll:  replace the period scan with a Python loop — used by the
      dry-run so XLA cost_analysis counts every layer (a `while` body is
      costed once, not ×trip-count).
    Returns (logits (B,S,vocab), caches pytree).
    """
    period, n_periods, rest = period_structure(cfg)
    if frames is not None:
        x = constrain(frames.astype(COMPUTE_DTYPE), "batch", None, None)
    else:
        x = embed_tokens(params, cfg, tokens)
    B, S, _ = x.shape
    positions = jnp.arange(S)

    def period_body(x, p_period):
        caches = []
        for s, kind in enumerate(period):
            x, ce = apply_block(p_period[f"slot{s}"], x, kind, cfg=cfg,
                                plan=plan, img=img, positions=positions,
                                unroll=unroll)
            caches.append(ce)
        return x, tuple(caches)

    body = period_body
    if remat_policy is not None:
        body = jax.checkpoint(period_body, policy=remat_policy,
                              prevent_cse=False)

    if n_periods > 0:
        if isinstance(params["periods"], (list, tuple)):
            # split form (dry-run): one leaf per layer — avoids stacked-leaf
            # slicing that XLA cost-analysis charges at full-tensor cost
            caches_list = []
            for p_i in params["periods"]:
                x, ce = body(x, p_i)
                caches_list.append(ce)
            period_caches = tuple(caches_list)
        elif unroll:
            caches_list = []
            for i in range(n_periods):
                p_i = jax.tree.map(lambda a: a[i], params["periods"])
                x, ce = body(x, p_i)
                caches_list.append(ce)
            period_caches = tuple(caches_list)
        else:
            x, period_caches = jax.lax.scan(body, x, params["periods"])
    else:
        period_caches = ()
    rest_caches = []
    for p_layer, kind in zip(params["rest"], rest):
        x, ce = apply_block(p_layer, x, kind, cfg=cfg, plan=plan, img=img,
                            positions=positions, unroll=unroll)
        rest_caches.append(ce)

    x = rms_norm(x, params["final_norm"], cfg.norm_eps)
    logits = (x.astype(COMPUTE_DTYPE)
              @ params["lm_head"].astype(COMPUTE_DTYPE)).astype(jnp.float32)
    logits = constrain(logits, "batch", None, "model")
    caches = {"periods": period_caches, "rest": tuple(rest_caches)}
    return logits, caches


# ---------------------------------------------------------------------------
# decode
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class CacheSpec:
    """Shapes for the decode cache of one arch at one shape cell."""
    cfg: ArchConfig
    seq_len: int

    def z_for(self, kind: str) -> int:
        if kind in ("attn", "xattn"):
            return (min(self.cfg.window, self.seq_len) if self.cfg.window
                    else self.seq_len)
        return 0


def init_cache(cfg: ArchConfig, batch: int, seq_len: int) -> PyTree:
    """Zero cache pytree matching the period structure."""
    period, n_periods, rest = period_structure(cfg)
    spec = CacheSpec(cfg, seq_len)
    E = cfg.resolved_head_dim

    def entry(kind: str):
        if kind in ("attn", "xattn"):
            Z = spec.z_for(kind)
            return {
                "k": jnp.zeros((batch, Z, cfg.n_kv_heads, E), COMPUTE_DTYPE),
                "v": jnp.zeros((batch, Z, cfg.n_kv_heads, E), COMPUTE_DTYPE),
                "pos_idx": jnp.full((Z,), -1, jnp.int32),
            }
        if kind == "rglru":
            return {"h": jnp.zeros((batch, cfg.d_model), jnp.float32)}
        if kind == "rwkv":
            return {"s": jnp.zeros((batch, cfg.n_heads, E, E), jnp.float32)}
        raise ValueError(kind)

    def stacked_entry(kind: str):
        return jax.tree.map(
            lambda z: jnp.broadcast_to(z, (n_periods,) + z.shape), entry(kind))

    return {
        "periods": {f"slot{s}": stacked_entry(kind)
                    for s, kind in enumerate(period)},
        "rest": [entry(kind) for kind in rest],
    }


def cache_pspecs(cfg: ArchConfig, batch: int, *, seq_len: int = 0,
                 tp: int = 16) -> PyTree:
    """Logical pspecs for the cache.

    Batch shards on "batch" when it divides; the TP axis goes on the
    kv-head dim when kv_heads % tp == 0, otherwise on the cache-length dim
    (sequence-sharded KV — the standard long-context fallback; softmax
    normalisation over the sharded axis lowers to psums)."""
    period, n_periods, rest = period_structure(cfg)
    batch_axis = "batch" if batch > 1 else None
    spec_obj = CacheSpec(cfg, seq_len or cfg.window or 1)

    def kv_spec(kind: str):
        Z = spec_obj.z_for(kind) if seq_len else 0
        if cfg.n_kv_heads % tp == 0:
            return (batch_axis, None, "model", None)
        if Z and Z % tp == 0:
            return (batch_axis, "model", None, None)
        return (batch_axis, None, None, None)

    def entry(kind: str):
        if kind in ("attn", "xattn"):
            return {"k": kv_spec(kind), "v": kv_spec(kind),
                    "pos_idx": (None,)}
        if kind == "rglru":
            return {"h": (batch_axis, "model")}
        if kind == "rwkv":
            return {"s": (batch_axis, "model", None, None)}
        raise ValueError(kind)

    def lifted(kind: str):
        return jax.tree.map(lambda sp: (None,) + tuple(sp), entry(kind),
                            is_leaf=lambda x: isinstance(x, tuple))

    return {"periods": {f"slot{s}": lifted(kind)
                        for s, kind in enumerate(period)},
            "rest": [entry(kind) for kind in rest]}


def _decode_block(p, cache, x, kind: str, pos, *, cfg: ArchConfig,
                  plan: CelloPlan) -> Tuple[jnp.ndarray, PyTree]:
    B = x.shape[0]
    H, KVH, E = cfg.n_heads, cfg.n_kv_heads, cfg.resolved_head_dim
    h = rms_norm(x, p["ln1"], cfg.norm_eps)
    if kind in ("attn", "xattn"):
        xc = h.astype(COMPUTE_DTYPE)
        q = (xc @ p["attn"]["wq"].astype(COMPUTE_DTYPE)).reshape(B, 1, H, E)
        k_new = (xc @ p["attn"]["wk"].astype(COMPUTE_DTYPE)
                 ).reshape(B, 1, KVH, E)
        v_new = (xc @ p["attn"]["wv"].astype(COMPUTE_DTYPE)
                 ).reshape(B, 1, KVH, E)
        q = apply_rope(q, pos[None], cfg.rope_theta)
        k_new = apply_rope(k_new, pos[None], cfg.rope_theta)
        Z = cache["k"].shape[1]
        slot = pos % Z
        if plan.cache_select_update:
            # shard-local write: broadcast-select keeps every shard's update
            # local even when Z is the sharded dim (no SPMD full-remat)
            hit = (jnp.arange(Z) == slot)[None, :, None, None]
            k_c = jnp.where(hit, k_new.astype(cache["k"].dtype), cache["k"])
            v_c = jnp.where(hit, v_new.astype(cache["v"].dtype), cache["v"])
            pos_idx = jnp.where(jnp.arange(Z) == slot,
                                pos.astype(jnp.int32), cache["pos_idx"])
        else:
            k_c = jax.lax.dynamic_update_slice_in_dim(cache["k"], k_new,
                                                      slot, 1)
            v_c = jax.lax.dynamic_update_slice_in_dim(cache["v"], v_new,
                                                      slot, 1)
            pos_idx = jax.lax.dynamic_update_slice_in_dim(
                cache["pos_idx"], pos[None].astype(jnp.int32), slot, 0)
        # mask by true positions (ring-buffer safe); grouped GQA einsums —
        # the repeated K/V never materialises (no reshard of the cache)
        valid = (pos_idx >= 0) & (pos_idx <= pos)
        if cfg.window:
            valid &= pos_idx > pos - cfg.window
        G = H // KVH
        qg = (q * jnp.asarray(E ** -0.5, q.dtype)).reshape(B, KVH, G, E)
        s = jnp.einsum("bkge,btke->bkgt", qg, k_c,
                       preferred_element_type=jnp.float32)
        s = jnp.where(valid[None, None, None, :], s, -1e30)
        pr = jax.nn.softmax(s, axis=-1)
        ctx = jnp.einsum("bkgt,btke->bkge", pr.astype(v_c.dtype), v_c,
                         preferred_element_type=jnp.float32)
        y = (ctx.reshape(B, 1, H * E).astype(COMPUTE_DTYPE)
             @ p["attn"]["wo"].astype(COMPUTE_DTYPE)).astype(x.dtype)
        new_cache = {"k": k_c, "v": v_c, "pos_idx": pos_idx}
    elif kind == "rglru":
        y, h_new = apply_rglru_step(p["rglru"], h, cache["h"])
        new_cache = {"h": h_new}
    elif kind == "rwkv":
        y, s_new = apply_rwkv_step(p["rwkv"], h, cache["s"], cfg.n_heads)
        new_cache = {"s": s_new}
    else:
        raise ValueError(kind)
    x = x + y
    h2 = rms_norm(x, p["ln2"], cfg.norm_eps)
    x = x + _mlp(p, h2, cfg, plan)
    return x, new_cache


def decode_step(params, cache, cfg: ArchConfig, plan: CelloPlan,
                tokens: jnp.ndarray, pos: jnp.ndarray, *,
                unroll: bool = False) -> Tuple[jnp.ndarray, PyTree]:
    """One decode step. tokens: (B, 1) int32; pos: () int32 current position.
    Returns (logits (B, 1, vocab), new_cache)."""
    period, n_periods, rest = period_structure(cfg)
    x = embed_tokens(params, cfg, tokens)

    def period_body(x, slices):
        p_period, c_period = slices
        new_c = {}
        for s, kind in enumerate(period):
            x, nc = _decode_block(p_period[f"slot{s}"], c_period[f"slot{s}"],
                                  x, kind, pos, cfg=cfg, plan=plan)
            new_c[f"slot{s}"] = nc
        return x, new_c

    if n_periods > 0:
        if isinstance(params["periods"], (list, tuple)):
            outs = []
            for p_i, c_i in zip(params["periods"], cache["periods"]):
                x, nc = period_body(x, (p_i, c_i))
                outs.append(nc)
            new_periods = outs                  # stays split
        elif unroll:
            outs = []
            for i in range(n_periods):
                sl = jax.tree.map(lambda a: a[i],
                                  (params["periods"], cache["periods"]))
                x, nc = period_body(x, sl)
                outs.append(nc)
            new_periods = jax.tree.map(lambda *xs: jnp.stack(xs), *outs)
        else:
            x, new_periods = jax.lax.scan(
                period_body, x, (params["periods"], cache["periods"]))
    else:
        new_periods = cache["periods"]
    new_rest = []
    for p_layer, c_layer, kind in zip(params["rest"], cache["rest"], rest):
        x, nc = _decode_block(p_layer, c_layer, x, kind, pos, cfg=cfg,
                              plan=plan)
        new_rest.append(nc)

    x = rms_norm(x, params["final_norm"], cfg.norm_eps)
    logits = (x.astype(COMPUTE_DTYPE)
              @ params["lm_head"].astype(COMPUTE_DTYPE)).astype(jnp.float32)
    return logits, {"periods": new_periods, "rest": new_rest}
