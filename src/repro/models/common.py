"""Shared model utilities: sharding context, dtype policy, RoPE, activations.

Sharding uses *logical* axis names resolved through a process-wide context
(`set_mesh_context`), so model code never hard-codes the physical mesh:

    logical axis   single-pod          multi-pod
    "batch"     -> ("data",)        -> ("pod", "data")
    "model"     -> ("model",)       -> ("model",)
    "seq"       -> used for sequence sharding in long-context cells

Outside a mesh context every constraint is a no-op — smoke tests on one CPU
device run the exact same model code.
"""
from __future__ import annotations

import threading
from typing import Optional

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

_CTX = threading.local()

COMPUTE_DTYPE = jnp.bfloat16
PARAM_DTYPE = jnp.float32


def set_mesh_context(mesh: Optional[Mesh]) -> None:
    _CTX.mesh = mesh
    if mesh is None:
        _CTX.axes = {}
        return
    names = mesh.axis_names
    _CTX.axes = {
        "batch": tuple(n for n in ("pod", "data") if n in names) or None,
        "model": "model" if "model" in names else None,
        "data": tuple(n for n in ("pod", "data") if n in names) or None,
    }


def get_mesh() -> Optional[Mesh]:
    return getattr(_CTX, "mesh", None)


def resolve_axis(name):
    if name is None:
        return None
    return getattr(_CTX, "axes", {}).get(name)


def pspec(*logical) -> P:
    return P(*(resolve_axis(a) for a in logical))


def _axis_size(mesh: Mesh, axis) -> int:
    if axis is None:
        return 1
    if isinstance(axis, (tuple, list)):
        n = 1
        for a in axis:
            n *= mesh.shape[a]
        return n
    return mesh.shape[axis]


def constrain(x: jnp.ndarray, *logical) -> jnp.ndarray:
    """with_sharding_constraint on logical axes; no-op without a mesh.

    Axes whose mesh extent does not divide the corresponding array dim are
    dropped (left to XLA's propagation) — e.g. recurrentgemma's 10 heads
    cannot shard 16-way, so the head axis stays unconstrained there.
    """
    mesh = get_mesh()
    if mesh is None:
        return x
    resolved = [resolve_axis(a) for a in logical]
    resolved += [None] * (x.ndim - len(resolved))
    safe = tuple(
        a if a is not None and d % _axis_size(mesh, a) == 0 else None
        for a, d in zip(resolved, x.shape))
    return jax.lax.with_sharding_constraint(x, NamedSharding(mesh, P(*safe)))


def named_sharding(*logical) -> Optional[NamedSharding]:
    mesh = get_mesh()
    if mesh is None:
        return None
    return NamedSharding(mesh, pspec(*logical))


# ---------------------------------------------------------------------------
# RoPE
# ---------------------------------------------------------------------------

def rope_freqs(head_dim: int, theta: float) -> jnp.ndarray:
    half = head_dim // 2
    return 1.0 / (theta ** (jnp.arange(0, half, dtype=jnp.float32) / half))


def apply_rope(x: jnp.ndarray, positions: jnp.ndarray,
               theta: float = 10_000.0) -> jnp.ndarray:
    """x: (..., S, H, E) or (..., S, E); positions: (..., S)."""
    E = x.shape[-1]
    freqs = rope_freqs(E, theta)                              # (E/2,)
    ang = positions[..., None].astype(jnp.float32) * freqs    # (..., S, E/2)
    cos, sin = jnp.cos(ang), jnp.sin(ang)
    extra = x.ndim - ang.ndim - 1
    for _ in range(extra):
        cos, sin = cos[..., None, :], sin[..., None, :]
    x1, x2 = x[..., :E // 2], x[..., E // 2:]
    xf1, xf2 = x1.astype(jnp.float32), x2.astype(jnp.float32)
    out = jnp.concatenate([xf1 * cos - xf2 * sin,
                           xf2 * cos + xf1 * sin], axis=-1)
    return out.astype(x.dtype)


# ---------------------------------------------------------------------------
# activations
# ---------------------------------------------------------------------------

def activation_fn(kind: str):
    if kind in ("swiglu", "silu"):
        return jax.nn.silu
    if kind in ("geglu", "gelu"):
        return lambda x: jax.nn.gelu(x, approximate=True)
    if kind == "relu2":
        return lambda x: jnp.square(jax.nn.relu(x))
    raise ValueError(kind)


def is_gated(kind: str) -> bool:
    return kind in ("swiglu", "geglu")


def rms_norm(x: jnp.ndarray, w: jnp.ndarray, eps: float = 1e-6) -> jnp.ndarray:
    xf = x.astype(jnp.float32)
    var = jnp.mean(xf * xf, axis=-1, keepdims=True)
    return (xf * jax.lax.rsqrt(var + eps) * (1.0 + w.astype(jnp.float32))
            ).astype(x.dtype)


def tag(x: jnp.ndarray, name: str) -> jnp.ndarray:
    """checkpoint_name tag — the hook the CELLO remat policy grips."""
    from jax.ad_checkpoint import checkpoint_name
    return checkpoint_name(x, name)
