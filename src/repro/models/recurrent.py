"""Recurrent blocks: RG-LRU (recurrentgemma) and RWKV-6 time-mix.

Both expose (a) a full-sequence form used by train/prefill (lowered either
through the Pallas kernel or the pure-JAX scan) and (b) a single-step form
used by decode, carrying the recurrent state — CELLO's canonical
explicit-buffer resident (it is read+written every token; the plan pins it).
"""
from __future__ import annotations

from typing import Dict, Tuple

import jax
import jax.numpy as jnp

from .common import COMPUTE_DTYPE, constrain, tag
from ..kernels.rglru.ref import RGLRU_C, rglru_reference
from ..kernels.rwkv6.ref import wkv6_reference


# ---------------------------------------------------------------------------
# RG-LRU block (Griffin recurrent block: proj → conv-less gated recurrence)
# ---------------------------------------------------------------------------

def init_rglru_params(key, d_model: int, dtype) -> Dict[str, jnp.ndarray]:
    ks = jax.random.split(key, 5)
    s = d_model ** -0.5
    return {
        "w_x": (jax.random.normal(ks[0], (d_model, d_model)) * s).astype(dtype),
        "w_gate_r": (jax.random.normal(ks[1], (d_model, d_model)) * s).astype(dtype),
        "w_gate_i": (jax.random.normal(ks[2], (d_model, d_model)) * s).astype(dtype),
        "w_out": (jax.random.normal(ks[3], (d_model, d_model)) * s).astype(dtype),
        "a_param": jnp.asarray(
            jax.random.uniform(ks[4], (d_model,), minval=0.9, maxval=1.1),
            jnp.float32),
    }


def rglru_pspecs() -> Dict[str, tuple]:
    # channel dim sharded on "model": the recurrence is elementwise in d
    return {"w_x": (None, "model"), "w_gate_r": (None, "model"),
            "w_gate_i": (None, "model"), "w_out": ("model", None),
            "a_param": ("model",)}


def apply_rglru_seq(params, x: jnp.ndarray, h0=None, *,
                    use_kernel: bool = False
                    ) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """x: (B,S,D) -> (y: (B,S,D), hT: (B,D))."""
    xc = x.astype(COMPUTE_DTYPE)
    xb = xc @ params["w_x"].astype(COMPUTE_DTYPE)
    gr = xc @ params["w_gate_r"].astype(COMPUTE_DTYPE)
    gi = xc @ params["w_gate_i"].astype(COMPUTE_DTYPE)
    xb = constrain(xb, "batch", None, "model")
    if use_kernel:
        from ..kernels.rglru import rglru as rglru_kernel
        h, hT = rglru_kernel(xb, gr, gi, params["a_param"], h0)
    else:
        h, hT = rglru_reference(xb, gr, gi, params["a_param"], h0)
    h = tag(h, "rnn_state")
    y = h.astype(COMPUTE_DTYPE) @ params["w_out"].astype(COMPUTE_DTYPE)
    return y.astype(x.dtype), hT


def apply_rglru_step(params, x: jnp.ndarray, h: jnp.ndarray
                     ) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """x: (B,1,D), h: (B,D) -> (y: (B,1,D), h')."""
    xc = x[:, 0].astype(COMPUTE_DTYPE)
    xb = (xc @ params["w_x"].astype(COMPUTE_DTYPE)).astype(jnp.float32)
    r = jax.nn.sigmoid((xc @ params["w_gate_r"].astype(COMPUTE_DTYPE))
                       .astype(jnp.float32))
    i = jax.nn.sigmoid((xc @ params["w_gate_i"].astype(COMPUTE_DTYPE))
                       .astype(jnp.float32))
    a = jnp.exp(-RGLRU_C * jax.nn.softplus(params["a_param"]) * r)
    beta = jnp.sqrt(jnp.maximum(1.0 - a * a, 1e-12))
    h_new = a * h + beta * (i * xb)
    y = (h_new.astype(COMPUTE_DTYPE) @ params["w_out"].astype(COMPUTE_DTYPE))
    return y[:, None].astype(x.dtype), h_new


# ---------------------------------------------------------------------------
# RWKV-6 time-mix block
# ---------------------------------------------------------------------------

def init_rwkv_params(key, d_model: int, n_heads: int, dtype
                     ) -> Dict[str, jnp.ndarray]:
    ks = jax.random.split(key, 7)
    s = d_model ** -0.5
    E = d_model // n_heads
    return {
        "w_r": (jax.random.normal(ks[0], (d_model, d_model)) * s).astype(dtype),
        "w_k": (jax.random.normal(ks[1], (d_model, d_model)) * s).astype(dtype),
        "w_v": (jax.random.normal(ks[2], (d_model, d_model)) * s).astype(dtype),
        "w_w": (jax.random.normal(ks[3], (d_model, d_model)) * s * 0.1
                ).astype(dtype),
        "w_o": (jax.random.normal(ks[4], (d_model, d_model)) * s).astype(dtype),
        "u": (jax.random.normal(ks[5], (n_heads, E)) * 0.1).astype(jnp.float32),
        "w_bias": (jax.random.normal(ks[6], (d_model,)) * 0.1 - 0.5
                   ).astype(jnp.float32),
    }


def rwkv_pspecs() -> Dict[str, tuple]:
    # head dim sharded on "model" (heads are independent in the recurrence)
    return {"w_r": (None, "model"), "w_k": (None, "model"),
            "w_v": (None, "model"), "w_w": (None, "model"),
            "w_o": ("model", None), "u": ("model", None),
            "w_bias": ("model",)}


def _split_heads(t: jnp.ndarray, H: int) -> jnp.ndarray:
    B, S, D = t.shape
    return t.reshape(B, S, H, D // H).transpose(0, 2, 1, 3)   # (B,H,S,E)


def apply_rwkv_seq(params, x: jnp.ndarray, n_heads: int, s0=None, *,
                   use_kernel: bool = False
                   ) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """x: (B,S,D) -> (y: (B,S,D), sT: (B,H,E,E))."""
    B, S, D = x.shape
    xc = x.astype(COMPUTE_DTYPE)
    r = _split_heads(xc @ params["w_r"].astype(COMPUTE_DTYPE), n_heads)
    k = _split_heads(xc @ params["w_k"].astype(COMPUTE_DTYPE), n_heads)
    v = _split_heads(xc @ params["w_v"].astype(COMPUTE_DTYPE), n_heads)
    w = _split_heads((xc @ params["w_w"].astype(COMPUTE_DTYPE))
                     .astype(jnp.float32)
                     + params["w_bias"].astype(jnp.float32), n_heads)
    if use_kernel:
        from ..kernels.rwkv6 import wkv6 as wkv6_kernel
        y, sT = wkv6_kernel(r, k, v, w, params["u"], s0)
    else:
        y, sT = wkv6_reference(r, k, v, w, params["u"], s0)
    y = tag(y, "rnn_state")
    y = y.transpose(0, 2, 1, 3).reshape(B, S, D)
    out = y.astype(COMPUTE_DTYPE) @ params["w_o"].astype(COMPUTE_DTYPE)
    return out.astype(x.dtype), sT


def apply_rwkv_step(params, x: jnp.ndarray, s: jnp.ndarray, n_heads: int
                    ) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """x: (B,1,D), s: (B,H,E,E) -> (y: (B,1,D), s')."""
    B, _, D = x.shape
    E = D // n_heads
    xc = x[:, 0].astype(COMPUTE_DTYPE)
    r = (xc @ params["w_r"].astype(COMPUTE_DTYPE)).reshape(B, n_heads, E)
    k = (xc @ params["w_k"].astype(COMPUTE_DTYPE)).reshape(B, n_heads, E)
    v = (xc @ params["w_v"].astype(COMPUTE_DTYPE)).reshape(B, n_heads, E)
    wt = ((xc @ params["w_w"].astype(COMPUTE_DTYPE)).astype(jnp.float32)
          + params["w_bias"]).reshape(B, n_heads, E)
    rf, kf, vf = (t.astype(jnp.float32) for t in (r, k, v))
    decay = jnp.exp(-jnp.exp(wt))
    kv = kf[..., :, None] * vf[..., None, :]                  # (B,H,E,E)
    y = jnp.einsum("bhi,bhij->bhj", rf,
                   s + params["u"][None, :, :, None] * kv)
    s_new = decay[..., :, None] * s + kv
    y = y.reshape(B, 1, D).astype(COMPUTE_DTYPE)
    out = y @ params["w_o"].astype(COMPUTE_DTYPE)
    return out.astype(x.dtype), s_new
