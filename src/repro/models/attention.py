"""Attention for the model zoo: chunked-flash (JAX), Pallas, naive, decode.

Three execution paths, selected by the CelloPlan:

* ``chunked_flash`` — pure-JAX online-softmax attention, blocked along KV
  with a `lax.scan`.  This is the *schedulable* form CELLO's fusion group
  lowers to on any backend: the score tile is bounded (S × kv_block) so the
  full score matrix never materialises.  Used by the dry-run so the HLO
  cost analysis reflects the fused schedule.
* ``pallas`` — the `repro.kernels.flash_attention` TPU kernel (explicit
  VMEM residency; interpret-mode on CPU).  Same math, kernel-level control.
* ``naive`` — materialises (B,H,S,T) scores.  This is the *seq-implicit
  baseline* of the paper: op-by-op execution with all intermediates round-
  tripping through the memory system.  Kept as a first-class config for the
  §Perf before/after measurements.
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp



NEG_INF = -1e30


def naive_attention(q, k, v, *, causal: bool, window: Optional[int] = None,
                    q_offset: int = 0) -> jnp.ndarray:
    """q: (B,S,H,E); k,v: (B,T,KVH,E) -> (B,S,H,E). Materialises scores."""
    B, S, H, E = q.shape
    T, KVH = k.shape[1], k.shape[2]
    rep = H // KVH
    scale = E ** -0.5
    kr = jnp.repeat(k, rep, axis=2) if rep > 1 else k
    vr = jnp.repeat(v, rep, axis=2) if rep > 1 else v
    s = jnp.einsum("bshe,bthe->bhst", q.astype(jnp.float32),
                   kr.astype(jnp.float32)) * scale
    qi = jnp.arange(S)[:, None] + q_offset
    kj = jnp.arange(T)[None, :]
    mask = jnp.ones((S, T), bool)
    if causal:
        mask &= kj <= qi
    if window is not None:
        mask &= kj > qi - window
    s = jnp.where(mask[None, None], s, NEG_INF)
    p = jax.nn.softmax(s, axis=-1)
    out = jnp.einsum("bhst,bthe->bshe", p, vr.astype(jnp.float32))
    return out.astype(q.dtype)


def chunked_flash_attention(q, k, v, *, causal: bool,
                            window: Optional[int] = None,
                            kv_block: int = 512,
                            q_offset: int = 0,
                            unroll: bool = False) -> jnp.ndarray:
    """Online-softmax attention blocked along KV (pure JAX lax.scan).

    q: (B,S,H,E); k,v: (B,T,KVH,E) -> (B,S,H,E).  Peak intermediate is the
    (B,H,S,kv_block) score tile — the CELLO fusion-group working set.
    """
    B, S, H, E = q.shape
    T, KVH = k.shape[1], k.shape[2]
    G = H // KVH                    # GQA group size — grouped einsums, no
    scale = E ** -0.5               # repeated K/V ever materialises
    kv_block = min(kv_block, T)
    Tp = -(-T // kv_block) * kv_block
    if Tp != T:
        k = jnp.pad(k, ((0, 0), (0, Tp - T), (0, 0), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, Tp - T), (0, 0), (0, 0)))
    nblk = Tp // kv_block

    # q: (B, KVH, G, S, E); k/v blocks: (nblk, B, KVH, kv_block, E)
    # operands stay in their storage dtype; contractions accumulate in f32
    # (preferred_element_type) so no full-tensor f32 copies materialise.
    qf = (q * jnp.asarray(scale, q.dtype)).reshape(B, S, KVH, G, E)
    qf = qf.transpose(0, 2, 3, 1, 4)
    kb = k.reshape(B, nblk, kv_block, KVH, E).transpose(1, 0, 3, 2, 4)
    vb = v.reshape(B, nblk, kv_block, KVH, E).transpose(1, 0, 3, 2, 4)

    qi = jnp.arange(S)[:, None] + q_offset                       # (S,1)

    def step(carry, blk):
        m, l, acc = carry
        kblk, vblk, j = blk                                      # (B,KVH,kb,E)
        s = jnp.einsum("bkgse,bkte->bkgst", qf, kblk,
                       preferred_element_type=jnp.float32)
        kj = j * kv_block + jnp.arange(kv_block)[None, :]        # (1,kb)
        mask = kj < T
        if causal:
            mask = mask & (kj <= qi)
        if window is not None:
            mask = mask & (kj > qi - window)
        s = jnp.where(mask[None, None, None], s, NEG_INF)
        m_new = jnp.maximum(m, s.max(-1, keepdims=True))
        alpha = jnp.exp(m - m_new)
        p = jnp.exp(s - m_new)
        l_new = alpha * l + p.sum(-1, keepdims=True)
        acc_new = acc * alpha + jnp.einsum(
            "bkgst,bkte->bkgse", p.astype(vblk.dtype), vblk,
            preferred_element_type=jnp.float32)
        return (m_new, l_new, acc_new), None

    init = (jnp.full((B, KVH, G, S, 1), NEG_INF, jnp.float32),
            jnp.zeros((B, KVH, G, S, 1), jnp.float32),
            jnp.zeros((B, KVH, G, S, E), jnp.float32))
    (m, l, acc), _ = jax.lax.scan(step, init,
                                  (kb, vb, jnp.arange(nblk)),
                                  unroll=nblk if unroll else 1)
    out = acc / jnp.maximum(l, 1e-30)                 # (B,KVH,G,S,E)
    out = out.transpose(0, 3, 1, 2, 4).reshape(B, S, H, E)
    return out.astype(q.dtype)


def pallas_attention(q, k, v, *, causal: bool, window: Optional[int] = None,
                     q_block: int = 512, kv_block: int = 512) -> jnp.ndarray:
    """(B,S,H,E)/(B,T,KVH,E) adapter over the Pallas kernel layout."""
    from ..kernels.flash_attention import flash_attention
    out = flash_attention(q.transpose(0, 2, 1, 3), k.transpose(0, 2, 1, 3),
                          v.transpose(0, 2, 1, 3), causal=causal,
                          window=window, q_block=q_block, kv_block=kv_block)
    return out.transpose(0, 2, 1, 3)


def decode_attention(q, k_cache, v_cache, pos, *,
                     window: Optional[int] = None) -> jnp.ndarray:
    """One-token attention against a cache.

    q: (B,1,H,E); caches: (B,Z,KVH,E); pos: () current position (the caches
    hold valid entries at [0, pos]).  Window masking matches the ring-buffer
    layout used by `transformer.Cache` (entries older than `window` are
    overwritten, so any valid cache slot is in-window by construction).
    """
    B, _, H, E = q.shape
    Z, KVH = k_cache.shape[1], k_cache.shape[2]
    G = H // KVH
    scale = E ** -0.5
    qg = (q * jnp.asarray(scale, q.dtype)).reshape(B, KVH, G, E)
    s = jnp.einsum("bkge,btke->bkgt", qg, k_cache,
                   preferred_element_type=jnp.float32)        # (B,KVH,G,Z)
    kj = jnp.arange(Z)[None, None, None, :]
    valid = kj <= pos
    if window is not None:
        valid &= kj > pos - window
    s = jnp.where(valid, s, NEG_INF)
    p = jax.nn.softmax(s, axis=-1)
    out = jnp.einsum("bkgt,btke->bkge", p.astype(v_cache.dtype), v_cache,
                     preferred_element_type=jnp.float32)
    return out.reshape(B, 1, H, E).astype(q.dtype)
