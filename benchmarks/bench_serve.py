"""Table 9 — batched serving throughput/latency vs sequential solves.

A deterministic load generator drives ``repro.serve.Server`` for one dense
and one sparse workload and reports, per row:

* ``seq32`` — the baseline the tentpole is measured against: 32 requests
  answered one at a time through eager per-request ``plan.run()`` (one
  compile-cache hit + one dispatch each, no batching).
* ``batch16`` — the same 32 requests submitted as a burst to a paused
  server, then served with ``max_batch_size=16``: the worker coalesces
  them into exactly ``ceil(32/16)`` batches, one vmapped dispatch each.
  ``speedup_vs_sequential`` is this row's ``requests_per_s`` over the
  ``seq32`` row's — the acceptance number (≥ 3× at batch ≥ 16).
* ``open@<rate>`` — open-loop arrival at a fixed rate (requests submitted
  on a timer, never waiting for results): measures the latency a steady
  client sees, p50/p99 end-to-end (queue wait + batch + dispatch).

Every row reports ``us_per_call`` (mean per-request latency — the shared
trajectory metric), ``requests_per_s``, ``p50_ms``/``p99_ms``, and the
batch shape that served it.  Requests use fixed seeds and a fixed arrival
schedule, and warmup passes (excluded) pre-pay tracing/compilation, so the
recorded trajectory (``BENCH_serve.json``) tracks serving-layer changes,
not compiler noise.  The bench-trajectory gate reads this table with the
multi-metric direction spec:
``requests_per_s:higher,p50_ms:lower,p99_ms:lower``.
"""
from __future__ import annotations

import time
from typing import Dict, List, Optional, Tuple

import numpy as np

#: serving-scale shapes: small enough that CI serves hundreds of solves,
#: large enough that a vmapped batch amortizes real per-request overhead
SERVE_SET = [
    ("cg", "cg", dict(n=256, iters=4)),
    ("cg_sparse/lap5", "cg_sparse", dict(n=256, iters=4)),
]

N_REQUESTS = 32          # burst size for seq / batch rows
MAX_BATCH = 16
MAX_WAIT_US = 2000.0
OPEN_RATES = (500, 2000)     # open-loop arrival rates, requests/sec
N_OPEN = 48                  # requests per open-loop row

# TABLE 10 (run_overload): sustained overload per admission policy.  The
# dispatch is pinned to a fixed duration with the fault-injection
# harness, so capacity — and therefore served_frac at a given overload
# factor — is set by construction, not by runner speed.
OVERLOAD_POLICIES = ("reject", "shed_oldest", "block")
OVERLOAD_N = 256             # offered requests per policy row
OVERLOAD_QUEUE = 16          # admission bound (max_queue)
OVERLOAD_FACTOR = 4          # offered rate = factor x capacity
OVERLOAD_SLOW_S = 0.03       # injected per-dispatch floor
OVERLOAD_DEADLINE_S = 0.25   # per-request deadline


def _percentiles(lat_s: List[float]) -> Tuple[float, float, float]:
    """(mean_us, p50_ms, p99_ms) of a latency sample."""
    arr = np.asarray(lat_s, dtype=np.float64)
    return (float(arr.mean() * 1e6),
            float(np.percentile(arr, 50) * 1e3),
            float(np.percentile(arr, 99) * 1e3))


def _row(name: str, backend: str, mean_us: float, rps: float, p50: float,
         p99: float, batches="", mean_batch="", speedup="") -> str:
    return (f"{name},{mean_us:.0f},{backend},{rps:.1f},{p50:.3f},"
            f"{p99:.3f},{batches},{mean_batch},{speedup}")


def _sequential(plan, program, backend: str) -> Tuple[float, List[float]]:
    """(requests/sec, per-request latencies) for eager one-at-a-time
    ``plan.run()`` — the unbatched serving baseline."""
    import jax

    from repro.frontends import make_feeds

    feeds = [make_feeds(program, seed=s) for s in range(N_REQUESTS)]
    jax.block_until_ready(plan.run(feeds[0], backend=backend))  # warmup
    lat = []
    t0 = time.perf_counter()
    for f in feeds:
        t1 = time.perf_counter()
        jax.block_until_ready(plan.run(f, backend=backend))
        lat.append(time.perf_counter() - t1)
    return N_REQUESTS / (time.perf_counter() - t0), lat


def _burst(router, reqs) -> Tuple[float, List[float], Dict]:
    """Serve ``reqs`` as one paused-submit burst: every request is queued
    before the worker starts, so coalescing is deterministic —
    ``ceil(len(reqs)/MAX_BATCH)`` batches, one dispatch each."""
    from repro.serve import ServeConfig, Server

    srv = Server(router, ServeConfig(max_batch_size=MAX_BATCH,
                                     max_wait_us=MAX_WAIT_US,
                                     autostart=False))
    futs = [srv.submit(r) for r in reqs]
    t0 = time.perf_counter()
    srv.start()
    results = [f.result(timeout=600) for f in futs]
    rps = len(reqs) / (time.perf_counter() - t0)
    srv.close()
    return rps, [r.latency_s for r in results], srv.stats()


def _open_loop(router, reqs, rate: float) -> Tuple[float, List[float]]:
    """Submit ``reqs`` on a fixed-interval clock (open loop: arrivals
    never wait for completions) and measure end-to-end latency."""
    from repro.serve import ServeConfig, Server

    interval = 1.0 / rate
    srv = Server(router, ServeConfig(max_batch_size=MAX_BATCH,
                                     max_wait_us=MAX_WAIT_US))
    t0 = time.perf_counter()
    futs = []
    for i, r in enumerate(reqs):
        target = t0 + i * interval
        delay = target - time.perf_counter()
        if delay > 0:
            time.sleep(delay)
        futs.append(srv.submit(r))
    results = [f.result(timeout=600) for f in futs]
    rps = len(reqs) / (time.perf_counter() - t0)
    srv.close()
    return rps, [r.latency_s for r in results]


def run(backend: Optional[str] = None) -> List[str]:
    from repro.serve import PlanRouter, request

    be = backend or "reference"
    router = PlanRouter()       # shared: plans compile once per bucket
    rows = ["name,us_per_call,backend,requests_per_s,p50_ms,p99_ms,"
            "batches,mean_batch,speedup_vs_sequential"]
    for label, wl, params in SERVE_SET:
        reqs = [request(wl, backend=be, seed=s, **params)
                for s in range(N_REQUESTS)]
        entry = router.plan_for(router.bucket(reqs[0]))
        # warm every padded batch size the server can form (jit retraces
        # per size; measurements track serving, not tracing)
        one = router.request_feeds(entry, reqs[0])
        b = 1
        while b <= MAX_BATCH:
            entry.bplan.run_many([one] * b, entry.shared_feeds)
            b *= 2

        seq_rps, seq_lat = _sequential(entry.bplan.plan, entry.program, be)
        mean_us, p50, p99 = _percentiles(seq_lat)
        rows.append(_row(f"hpc/{label}/seq{N_REQUESTS}", be, mean_us,
                         seq_rps, p50, p99, batches=N_REQUESTS,
                         mean_batch=1))

        _burst(router, reqs)                 # warmup: pays the B=16 trace
        d0 = entry.bplan.stats["dispatches"]
        rps, lat, stats = _burst(router, reqs)
        served = stats["buckets"][entry.key.label]
        n_batches = entry.bplan.stats["dispatches"] - d0
        mean_us, p50, p99 = _percentiles(lat)
        rows.append(_row(
            f"hpc/{label}/batch{MAX_BATCH}", be, mean_us, rps, p50, p99,
            batches=n_batches,
            mean_batch=f"{N_REQUESTS / max(n_batches, 1):.1f}",
            speedup=f"{rps / seq_rps:.2f}"))
        assert served["queued"] == 0

        for rate in OPEN_RATES:
            open_reqs = [request(wl, backend=be, seed=s, **params)
                         for s in range(N_OPEN)]
            d0 = entry.bplan.stats["dispatches"]
            rps, lat = _open_loop(router, open_reqs, rate)
            n_batches = entry.bplan.stats["dispatches"] - d0
            mean_us, p50, p99 = _percentiles(lat)
            rows.append(_row(
                f"hpc/{label}/open@{rate}", be, mean_us, rps, p50, p99,
                batches=n_batches,
                mean_batch=f"{N_OPEN / max(n_batches, 1):.1f}"))
    return rows


def run_overload(backend: Optional[str] = None) -> List[str]:
    """TABLE 10 — open-loop arrivals at ``OVERLOAD_FACTOR`` x capacity,
    one row per admission policy.

    Dispatch duration is pinned at ``OVERLOAD_SLOW_S`` via
    ``repro.testing.faults`` (site ``serve.dispatch``), so capacity is
    ``MAX_BATCH / OVERLOAD_SLOW_S`` requests/sec *by construction* and
    ``served_frac`` is a deterministic function of the admission policy
    rather than of runner speed — which is what makes it gateable:

    * ``reject`` / ``shed_oldest`` — the queue bound sheds ~3/4 of the
      offered load (factor 4): ``served_frac`` ~ 1/factor, every refused
      request fails fast and typed, ``overload_p99_ms`` stays bounded by
      queue depth x dispatch time.
    * ``block`` — admission backpressure throttles the client to
      capacity: ``served_frac`` ~ 1.0 at the cost of submit-side
      waiting (bounded by the per-request deadline).
    """
    from repro.serve import (DeadlineExceeded, Overloaded, PlanRouter,
                             ServeConfig, Server, request)
    from repro.testing import faults

    be = backend or "reference"
    router = PlanRouter()
    label, wl, params = SERVE_SET[0]
    capacity = MAX_BATCH / OVERLOAD_SLOW_S
    offered = OVERLOAD_FACTOR * capacity
    interval = 1.0 / offered
    rows = ["name,us_per_call,backend,offered_rps,served_frac,shed_rate,"
            "deadline_miss_rate,overload_p99_ms"]

    # warm every padded batch size the worker can form (jit retraces per
    # size; the injected floor, not tracing, must set the dispatch time)
    req0 = request(wl, backend=be, seed=0, **params)
    entry = router.plan_for(router.bucket(req0))
    one = router.request_feeds(entry, req0)
    b = 1
    while b <= MAX_BATCH:
        entry.bplan.run_many([one] * b, entry.shared_feeds)
        b *= 2

    for policy in OVERLOAD_POLICIES:
        srv = Server(router, ServeConfig(max_batch_size=MAX_BATCH,
                                         max_wait_us=MAX_WAIT_US,
                                         max_queue=OVERLOAD_QUEUE,
                                         overload=policy))
        futs: List = []
        shed = missed = 0
        with faults.inject("serve.dispatch", kind="slow",
                           delay_s=OVERLOAD_SLOW_S):
            t0 = time.perf_counter()
            for s in range(OVERLOAD_N):
                target = t0 + s * interval
                delay = target - time.perf_counter()
                if delay > 0:
                    time.sleep(delay)
                try:
                    futs.append(srv.submit(
                        request(wl, backend=be, seed=s % 17, **params),
                        deadline_s=OVERLOAD_DEADLINE_S))
                except Overloaded:            # reject: refused at submit
                    shed += 1
                except DeadlineExceeded:      # block: admission expired
                    missed += 1
            served_lat: List[float] = []
            for f in futs:
                try:
                    served_lat.append(f.result(timeout=600).latency_s)
                except Overloaded:            # shed_oldest: failed queued
                    shed += 1
                except DeadlineExceeded:      # expired in queue
                    missed += 1
        srv.close()
        mean_us, _, p99 = (_percentiles(served_lat) if served_lat
                           else (0.0, 0.0, 0.0))
        rows.append(
            f"hpc/{label}/overload@{policy},{mean_us:.0f},{be},"
            f"{offered:.0f},{len(served_lat) / OVERLOAD_N:.3f},"
            f"{shed / OVERLOAD_N:.3f},{missed / OVERLOAD_N:.3f},"
            f"{p99:.3f}")
    return rows


if __name__ == "__main__":
    print("\n".join(run()))
    print("\n".join(run_overload()))
