"""Table 9 — batched serving throughput/latency vs sequential solves.

A deterministic load generator drives ``repro.serve.Server`` for one dense
and one sparse workload and reports, per row:

* ``seq32`` — the baseline the tentpole is measured against: 32 requests
  answered one at a time through eager per-request ``plan.run()`` (one
  compile-cache hit + one dispatch each, no batching).
* ``batch16`` — the same 32 requests submitted as a burst to a paused
  server, then served with ``max_batch_size=16``: the worker coalesces
  them into exactly ``ceil(32/16)`` batches, one vmapped dispatch each.
  ``speedup_vs_sequential`` is this row's ``requests_per_s`` over the
  ``seq32`` row's — the acceptance number (≥ 3× at batch ≥ 16).
* ``open@<rate>`` — open-loop arrival at a fixed rate (requests submitted
  on a timer, never waiting for results): measures the latency a steady
  client sees, p50/p99 end-to-end (queue wait + batch + dispatch).

Every row reports ``us_per_call`` (mean per-request latency — the shared
trajectory metric), ``requests_per_s``, ``p50_ms``/``p99_ms``, and the
batch shape that served it.  Requests use fixed seeds and a fixed arrival
schedule, and warmup passes (excluded) pre-pay tracing/compilation, so the
recorded trajectory (``BENCH_serve.json``) tracks serving-layer changes,
not compiler noise.  The bench-trajectory gate reads this table with the
multi-metric direction spec:
``requests_per_s:higher,p50_ms:lower,p99_ms:lower``.
"""
from __future__ import annotations

import time
from typing import Dict, List, Optional, Tuple

import numpy as np

#: serving-scale shapes: small enough that CI serves hundreds of solves,
#: large enough that a vmapped batch amortizes real per-request overhead
SERVE_SET = [
    ("cg", "cg", dict(n=256, iters=4)),
    ("cg_sparse/lap5", "cg_sparse", dict(n=256, iters=4)),
]

N_REQUESTS = 32          # burst size for seq / batch rows
MAX_BATCH = 16
MAX_WAIT_US = 2000.0
OPEN_RATES = (500, 2000)     # open-loop arrival rates, requests/sec
N_OPEN = 48                  # requests per open-loop row


def _percentiles(lat_s: List[float]) -> Tuple[float, float, float]:
    """(mean_us, p50_ms, p99_ms) of a latency sample."""
    arr = np.asarray(lat_s, dtype=np.float64)
    return (float(arr.mean() * 1e6),
            float(np.percentile(arr, 50) * 1e3),
            float(np.percentile(arr, 99) * 1e3))


def _row(name: str, backend: str, mean_us: float, rps: float, p50: float,
         p99: float, batches="", mean_batch="", speedup="") -> str:
    return (f"{name},{mean_us:.0f},{backend},{rps:.1f},{p50:.3f},"
            f"{p99:.3f},{batches},{mean_batch},{speedup}")


def _sequential(plan, program, backend: str) -> Tuple[float, List[float]]:
    """(requests/sec, per-request latencies) for eager one-at-a-time
    ``plan.run()`` — the unbatched serving baseline."""
    import jax

    from repro.frontends import make_feeds

    feeds = [make_feeds(program, seed=s) for s in range(N_REQUESTS)]
    jax.block_until_ready(plan.run(feeds[0], backend=backend))  # warmup
    lat = []
    t0 = time.perf_counter()
    for f in feeds:
        t1 = time.perf_counter()
        jax.block_until_ready(plan.run(f, backend=backend))
        lat.append(time.perf_counter() - t1)
    return N_REQUESTS / (time.perf_counter() - t0), lat


def _burst(router, reqs) -> Tuple[float, List[float], Dict]:
    """Serve ``reqs`` as one paused-submit burst: every request is queued
    before the worker starts, so coalescing is deterministic —
    ``ceil(len(reqs)/MAX_BATCH)`` batches, one dispatch each."""
    from repro.serve import Server

    srv = Server(router, max_batch_size=MAX_BATCH,
                 max_wait_us=MAX_WAIT_US, autostart=False)
    futs = [srv.submit(r) for r in reqs]
    t0 = time.perf_counter()
    srv.start()
    results = [f.result(timeout=600) for f in futs]
    rps = len(reqs) / (time.perf_counter() - t0)
    srv.close()
    return rps, [r.latency_s for r in results], srv.stats()


def _open_loop(router, reqs, rate: float) -> Tuple[float, List[float]]:
    """Submit ``reqs`` on a fixed-interval clock (open loop: arrivals
    never wait for completions) and measure end-to-end latency."""
    from repro.serve import Server

    interval = 1.0 / rate
    srv = Server(router, max_batch_size=MAX_BATCH,
                 max_wait_us=MAX_WAIT_US)
    t0 = time.perf_counter()
    futs = []
    for i, r in enumerate(reqs):
        target = t0 + i * interval
        delay = target - time.perf_counter()
        if delay > 0:
            time.sleep(delay)
        futs.append(srv.submit(r))
    results = [f.result(timeout=600) for f in futs]
    rps = len(reqs) / (time.perf_counter() - t0)
    srv.close()
    return rps, [r.latency_s for r in results]


def run(backend: Optional[str] = None) -> List[str]:
    from repro.serve import PlanRouter, request

    be = backend or "reference"
    router = PlanRouter()       # shared: plans compile once per bucket
    rows = ["name,us_per_call,backend,requests_per_s,p50_ms,p99_ms,"
            "batches,mean_batch,speedup_vs_sequential"]
    for label, wl, params in SERVE_SET:
        reqs = [request(wl, backend=be, seed=s, **params)
                for s in range(N_REQUESTS)]
        entry = router.plan_for(router.bucket(reqs[0]))
        # warm every padded batch size the server can form (jit retraces
        # per size; measurements track serving, not tracing)
        one = router.request_feeds(entry, reqs[0])
        b = 1
        while b <= MAX_BATCH:
            entry.bplan.run_many([one] * b, entry.shared_feeds)
            b *= 2

        seq_rps, seq_lat = _sequential(entry.bplan.plan, entry.program, be)
        mean_us, p50, p99 = _percentiles(seq_lat)
        rows.append(_row(f"hpc/{label}/seq{N_REQUESTS}", be, mean_us,
                         seq_rps, p50, p99, batches=N_REQUESTS,
                         mean_batch=1))

        _burst(router, reqs)                 # warmup: pays the B=16 trace
        d0 = entry.bplan.stats["dispatches"]
        rps, lat, stats = _burst(router, reqs)
        served = stats["buckets"][entry.key.label]
        n_batches = entry.bplan.stats["dispatches"] - d0
        mean_us, p50, p99 = _percentiles(lat)
        rows.append(_row(
            f"hpc/{label}/batch{MAX_BATCH}", be, mean_us, rps, p50, p99,
            batches=n_batches,
            mean_batch=f"{N_REQUESTS / max(n_batches, 1):.1f}",
            speedup=f"{rps / seq_rps:.2f}"))
        assert served["queued"] == 0

        for rate in OPEN_RATES:
            open_reqs = [request(wl, backend=be, seed=s, **params)
                         for s in range(N_OPEN)]
            d0 = entry.bplan.stats["dispatches"]
            rps, lat = _open_loop(router, open_reqs, rate)
            n_batches = entry.bplan.stats["dispatches"] - d0
            mean_us, p50, p99 = _percentiles(lat)
            rows.append(_row(
                f"hpc/{label}/open@{rate}", be, mean_us, rps, p50, p99,
                batches=n_batches,
                mean_batch=f"{N_OPEN / max(n_batches, 1):.1f}"))
    return rows


if __name__ == "__main__":
    print("\n".join(run()))
