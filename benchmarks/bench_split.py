"""Table 4 — the co-design sweep itself: time vs explicit/implicit split,
and the chosen split per workload (CELLO's central knob)."""
from __future__ import annotations

import time
from typing import List

from .workloads import workloads

SPLITS = (0.0, 0.25, 0.5, 0.75, 1.0)


def run() -> List[str]:
    rows = ["workload,us_per_call,cached,best_split," +
            ",".join(f"time_ms@{s}" for s in SPLITS)]
    for name, build in workloads():
        traced = build()
        t0 = time.perf_counter()
        res = traced.codesign()
        us = (time.perf_counter() - t0) * 1e6
        sweep = res.split_sweep
        cells = [f"{sweep[s].time_s * 1e3:.3f}" if s in sweep else ""
                 for s in SPLITS]
        rows.append(f"{name},{us:.0f},{int(res.from_cache)},"
                    f"{res.best.schedule.config.explicit_frac}," +
                    ",".join(cells))
    return rows


if __name__ == "__main__":
    print("\n".join(run()))
