"""Benchmark harness — one table per paper-style experiment.
Prints ``name,us_per_call,derived`` CSV blocks."""
from __future__ import annotations

import sys


def main() -> None:
    from . import (bench_speedup, bench_energy, bench_capacity, bench_split,
                   bench_kernels, bench_roofline)
    tables = [
        ("TABLE 1 — CELLO speedup vs baselines", bench_speedup),
        ("TABLE 2 — energy vs baselines", bench_energy),
        ("TABLE 3 — HBM traffic vs buffer capacity", bench_capacity),
        ("TABLE 4 — explicit/implicit split co-design sweep", bench_split),
        ("TABLE 5 — kernel microbench (interpret) + correctness",
         bench_kernels),
        ("TABLE 6 — roofline terms from the multi-pod dry-run",
         bench_roofline),
    ]
    failures = 0
    for title, mod in tables:
        print(f"\n# {title}")
        try:
            for row in mod.run():
                print(row)
        except Exception as e:                       # pragma: no cover
            failures += 1
            print(f"ERROR,{type(e).__name__}: {e}")
    if failures:
        sys.exit(1)


if __name__ == "__main__":
    main()
