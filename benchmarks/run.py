"""Benchmark harness — one table per paper-style experiment.

Prints ``name,us_per_call,derived...`` CSV blocks; on a table failure the
full traceback is printed (CI logs must be debuggable) before the
``ERROR,...`` row.

``--json PATH`` additionally writes a machine-readable dump
``{table_title: [{name, us_per_call, backend, derived}, ...]}`` so the
per-PR perf trajectory (``BENCH_*.json``) can be recorded and diffed.
Two non-table keys ride along (``scripts/bench_compare.py`` skips them
when diffing): ``meta`` — jax/jaxlib/python versions, platform, device
backend, x64 flag, UTC timestamp — and ``obs`` — the run's
``repro.obs`` metrics snapshot.
``--tables`` filters tables by case-insensitive substring (comma-separated),
which is what the CI smoke job uses to run one cheap table.  ``--backend``
threads an execution backend into the tables that run plans for real (the
HPC tables 7/8): TABLE 8 restricts to that backend, TABLE 7 gains measured
``run_us`` wall-clock next to its model columns.  ``--repeats N`` threads a
repeat count into the measuring tables: each timing is the **median of N
runs after one excluded warmup** (the warmup pays tracing/compilation), so
recorded trajectories (and the `scripts/bench_compare.py` regression gate)
compare medians, not first-run noise.
"""
from __future__ import annotations

import argparse
import inspect
import json
import sys
import traceback
from typing import Any, Dict, List


def _tables():
    from . import (bench_speedup, bench_energy, bench_capacity, bench_split,
                   bench_kernels, bench_roofline, bench_hpc, bench_exec,
                   bench_serve, bench_overload, bench_dist)
    return [
        ("TABLE 1 — CELLO speedup vs baselines", bench_speedup),
        ("TABLE 2 — energy vs baselines", bench_energy),
        ("TABLE 3 — HBM traffic vs buffer capacity", bench_capacity),
        ("TABLE 4 — explicit/implicit split co-design sweep", bench_split),
        ("TABLE 5 — kernel microbench (interpret) + correctness",
         bench_kernels),
        ("TABLE 6 — roofline terms from the multi-pod dry-run",
         bench_roofline),
        ("TABLE 7 — HPC DAG speedup vs implicit/explicit/fused baselines",
         bench_hpc),
        ("TABLE 8 — measured wall-clock per execution backend",
         bench_exec),
        ("TABLE 9 — batched serving throughput vs sequential solves",
         bench_serve),
        # shares the BENCH_serve.json dump with TABLE 9: its rows use
        # disjoint metric names (served_frac/shed_rate/... vs
        # requests_per_s/p50_ms/p99_ms) so each gate skips the other's
        ("TABLE 10 — serving under overload per admission policy",
         bench_overload),
        ("TABLE 11 — distributed co-design: per-shard pin crossover",
         bench_dist),
    ]


def _meta(backend: str = None) -> Dict[str, Any]:
    """Provenance block for ``--json`` dumps: enough to tell whether two
    recorded trajectories are comparable (same jax/jaxlib, same device
    class, same x64 mode).  Lives under the top-level ``meta`` key, which
    ``scripts/bench_compare.py`` skips when diffing rows."""
    import datetime
    import platform
    meta: Dict[str, Any] = {
        "python": platform.python_version(),
        "platform": platform.platform(),
        "backend_flag": backend,
        "timestamp": datetime.datetime.now(
            datetime.timezone.utc).isoformat(),
    }
    try:
        import jax
        import jaxlib
        meta["jax"] = jax.__version__
        meta["jaxlib"] = getattr(jaxlib, "__version__", None)
        meta["jax_backend"] = jax.default_backend()
        meta["x64"] = bool(jax.config.jax_enable_x64)
    except Exception:                                 # pragma: no cover
        meta["jax"] = None
    return meta


def _maybe_number(cell: str) -> Any:
    for cast in (int, float):
        try:
            return cast(cell)
        except ValueError:
            continue
    return cell


def _records(rows: List[str],
             backend: str = None) -> List[Dict[str, Any]]:
    """CSV block -> [{name, us_per_call, backend, derived}] (header row
    first).  ``backend`` records which execution backend produced the
    wall-clock; a per-row ``backend`` column wins over the global flag,
    and model-only tables record ``None``."""
    if not rows:
        return []
    header = rows[0].split(",")
    out = []
    for line in rows[1:]:
        cells = line.split(",")
        rec: Dict[str, Any] = {"name": cells[0], "us_per_call": None,
                               "backend": backend, "derived": {}}
        for col, cell in zip(header[1:], cells[1:]):
            if col == "us_per_call":
                try:
                    rec["us_per_call"] = float(cell)
                except ValueError:
                    pass
            elif col == "backend":
                rec["backend"] = cell
            else:
                rec["derived"][col] = _maybe_number(cell)
        out.append(rec)
    return out


def main(argv=None) -> None:
    ap = argparse.ArgumentParser(
        prog="python -m benchmarks.run",
        description="Run the paper-style benchmark tables.")
    ap.add_argument("--json", metavar="PATH",
                    help="write a machine-readable row dump to PATH")
    ap.add_argument("--tables", metavar="FILTERS",
                    help="comma-separated case-insensitive substrings; only "
                         "matching table titles run (e.g. --tables hpc)")
    ap.add_argument("--backend", metavar="NAME",
                    help="execution backend for the tables that run plans "
                         "for real (reference | pallas | any registered "
                         "name); threaded into the HPC tables")
    ap.add_argument("--repeats", metavar="N", type=int,
                    help="timed repetitions per measurement (median "
                         "reported, one warmup excluded); threaded into "
                         "the tables that accept it")
    args = ap.parse_args(argv)
    wanted = ([f.strip().lower() for f in args.tables.split(",") if f.strip()]
              if args.tables else None)

    failures = 0
    dump: Dict[str, List[Dict[str, Any]]] = {}
    ran = 0
    for title, mod in _tables():
        if wanted and not any(w in title.lower() for w in wanted):
            continue
        ran += 1
        print(f"\n# {title}")
        kwargs = {}
        params = inspect.signature(mod.run).parameters
        if args.backend and "backend" in params:
            kwargs["backend"] = args.backend
        if args.repeats and "repeats" in params:
            kwargs["repeats"] = args.repeats
        try:
            rows = list(mod.run(**kwargs))
        except Exception as e:                       # pragma: no cover
            failures += 1
            traceback.print_exc(file=sys.stdout)
            print(f"ERROR,{type(e).__name__}: {e}")
            dump[title] = []
        else:
            for row in rows:
                print(row)
            # only tables that actually received the backend kwarg ran a
            # backend; model-only tables keep backend=None in the dump
            dump[title] = _records(rows, backend=kwargs.get("backend"))
    if wanted and not ran:
        print(f"no table title matches {args.tables!r}", file=sys.stderr)
        sys.exit(2)
    if args.json:
        out: Dict[str, Any] = dict(dump)
        out["meta"] = _meta(args.backend)
        try:
            from repro import obs
            out["obs"] = obs.snapshot()
        except Exception:                             # pragma: no cover
            pass
        with open(args.json, "w") as f:
            json.dump(out, f, indent=2, sort_keys=True)
        print(f"\nwrote {args.json}")
    if failures:
        sys.exit(1)


if __name__ == "__main__":
    main()
