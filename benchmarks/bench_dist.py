"""Table 11 — distributed co-design: the per-shard pin crossover.

The paper's distributed claim: an operator too large for one device's
explicit region pins once the DAG is partitioned over a wide-enough
mesh, because each shard only holds a 1/K row block — the schedule ×
buffer search re-runs against the mesh's *aggregate* capacity K·C
(``Session.lower(mesh=K)``).  The table sweeps K at one per-device
capacity and records when the operator crosses into the pinned regime
and what the co-design model claims for it.

Rows are ``{workload}/n{n}/K{k}``; ``us_per_call`` is the sharded
lowering wall-clock (re-codesign at K·C + ``partition_plan``), so the
recorded trajectory also tracks the partitioning overhead.  ``pinned_A``
is the crossover bit: 0 while the operator streams, 1 once the aggregate
region holds it.  ``gathers``/``psums``/``halo`` count the exchange sets
the partition derived; ``csr_pad`` is the padded per-shard entry window
for CSR operands (0 for dense).  Everything is model + partition level —
no forced device count needed, so the table runs in any CI job; the
``distributed-smoke`` job additionally executes sharded plans for real
on 8 forced host devices (``tests/test_distributed.py``).
"""
from __future__ import annotations

import time
from typing import List, Optional

from repro.api import CodesignConfig, Session
from repro.core.buffer import MiB

#: per-device explicit/implicit capacity: A (4 MiB at n=1024 fp32) never
#: fits one device, fits the aggregate region from K=8 on
CAPACITY = 1 * MiB
SHARDS = (1, 2, 4, 8)
POINTS = (("cg", 1024, dict(iters=4)),
          ("cg_sparse", 1024, dict(iters=4)))


def run(backend: Optional[str] = None,
        repeats: Optional[int] = None) -> List[str]:
    rows = ["workload,us_per_call,K,capacity_kib,aggregate_kib,pinned_A,"
            "speedup_vs_implicit,gathers,psums,halo,csr_pad,pinned"]
    for wl, n, params in POINTS:
        sess = Session()
        traced = sess.trace(workload=wl, n=n, **params)
        cd = sess.codesign(traced,
                           CodesignConfig(capacity_bytes=CAPACITY))
        for k in SHARDS:
            t0 = time.perf_counter()
            plan = sess.lower(cd, mesh=k)
            us = (time.perf_counter() - t0) * 1e6
            dcd = plan.codesigned
            pins = dcd.best.schedule.pins
            # the dense operator is 'A'; the sparse one pins as its CSR
            # triple — count either as the crossover bit
            pinned_a = int("A" in pins
                           or any(p.startswith("A.") for p in pins))
            sp = plan.sharded
            pad = max((lay.pad_entries for lay in sp.csr), default=0)
            pinned = "+".join(sorted(pins)) if pins else "(none)"
            rows.append(
                f"{wl}/n{n}/K{k},{us:.0f},{k},"
                f"{CAPACITY >> 10},{(CAPACITY * k) >> 10},{pinned_a},"
                f"{dcd.speedup():.3f},{len(sp.gathered)},"
                f"{len(sp.reduced)},{len(sp.halo)},{pad},{pinned}")
    return rows


if __name__ == "__main__":
    print("\n".join(run()))
