"""Shared benchmark workload set: representative (arch × shape) layer graphs
for the CELLO analysis tables (speedup / energy / capacity / split)."""
from __future__ import annotations

from repro.configs import get_config
from repro.core import decode_graph, layer_graph

# (name, builder) — per-layer analysis graphs at paper-table shapes
def workloads():
    out = []
    for arch, batch, seq in [
        ("granite-3-8b", 4, 4096),
        ("gemma-7b", 4, 4096),
        ("minitron-8b", 4, 4096),
        ("h2o-danube-1.8b", 4, 4096),
        ("llama-3.2-vision-11b", 4, 4096),
        ("hubert-xlarge", 8, 4096),
        ("recurrentgemma-2b", 4, 4096),
        ("rwkv6-7b", 4, 4096),
        ("moonshot-v1-16b-a3b", 4, 4096),
        ("granite-moe-1b-a400m", 4, 4096),
    ]:
        cfg = get_config(arch)
        kinds = cfg.layer_kinds()
        kind = "xattn" if "xattn" in kinds else kinds[0]
        out.append((f"{arch}/train4k",
                    lambda c=cfg, b=batch, s=seq, k=kind:
                    layer_graph(c, b, s, layer_kind=k)))
    for arch in ("granite-3-8b", "gemma-7b"):
        cfg = get_config(arch)
        out.append((f"{arch}/prefill32k",
                    lambda c=cfg: layer_graph(c, 1, 32768)))
        out.append((f"{arch}/decode32k",
                    lambda c=cfg: decode_graph(c, 128, 32768)))
    return out
