"""Shared benchmark workload set: representative (arch × shape) traces
for the CELLO analysis tables (speedup / energy / capacity / split).

Each entry is ``(name, build)`` where ``build()`` returns a
``repro.api.TracedGraph``; benches run ``.codesign(...)`` on it, which hits
the shared disk cache on repeated runs.
"""
from __future__ import annotations

from repro.api import Session


def workloads():
    out = []
    for arch, batch, seq in [
        ("granite-3-8b", 4, 4096),
        ("gemma-7b", 4, 4096),
        ("minitron-8b", 4, 4096),
        ("h2o-danube-1.8b", 4, 4096),
        ("llama-3.2-vision-11b", 4, 4096),
        ("hubert-xlarge", 8, 4096),
        ("recurrentgemma-2b", 4, 4096),
        ("rwkv6-7b", 4, 4096),
        ("moonshot-v1-16b-a3b", 4, 4096),
        ("granite-moe-1b-a400m", 4, 4096),
    ]:
        sess = Session(arch)
        kinds = sess.cfg.layer_kinds()
        kind = "xattn" if "xattn" in kinds else kinds[0]
        out.append((f"{arch}/train4k",
                    lambda s=sess, b=batch, q=seq, k=kind:
                    s.trace(phase="train", batch=b, seq=q, layer_kind=k)))
    for arch in ("granite-3-8b", "gemma-7b"):
        sess = Session(arch)
        out.append((f"{arch}/prefill32k",
                    lambda s=sess: s.trace(phase="prefill", batch=1,
                                           seq=32768)))
        out.append((f"{arch}/decode32k",
                    lambda s=sess: s.trace(phase="decode", batch=128,
                                           kv_len=32768)))
    return out


#: paper-style HPC DAGs (frontend traces) for the TABLE 7 bench, as
#: ``(label, workload, params)``: skewed (n×n)·(n,) operators sized so the
#: fp64 dense operator is at/near the 128 MiB on-chip capacity — where the
#: implicit-only baseline thrashes and the co-designed explicit pin
#: captures the cross-iteration reuse.  The ``*_sparse`` rows are the
#: paper's true sparse operating points (5-point Laplacian ≈ 0.12%,
#: random 0.1% / 1%, banded): the operand's *nnz footprint* — not its
#: dense n² silhouette — is what competes for capacity, so the
#: pin-vs-stream crossover moves.
HPC_SET = [
    ("cg", "cg", dict(n=4096, iters=4)),
    ("bicgstab", "bicgstab", dict(n=4096, iters=3)),
    ("gmres", "gmres", dict(n=4096, restart=8)),
    ("jacobi2d", "jacobi2d", dict(n=4096, sweeps=8)),
    ("power_iteration", "power_iteration", dict(n=4096, iters=8)),
    ("mttkrp", "mttkrp", dict(i=256, j=256, k=256, rank=64)),
    ("cg_sparse/lap5", "cg_sparse", dict(n=4096, iters=4)),
    ("cg_sparse/d0.001", "cg_sparse",
     dict(n=4096, iters=4, pattern="random", density=0.001)),
    ("cg_sparse/d0.01", "cg_sparse",
     dict(n=4096, iters=4, pattern="random", density=0.01)),
    ("cg_sparse/band64", "cg_sparse",
     dict(n=4096, iters=4, pattern="banded", bandwidth=64)),
    ("bicgstab_sparse/d0.01", "bicgstab_sparse",
     dict(n=4096, iters=3, pattern="random", density=0.01)),
    ("jacobi_sparse/lap5", "jacobi_sparse", dict(n=4096, sweeps=8)),
]


def hpc_workloads():
    """``(name, build)`` pairs like :func:`workloads`, over ``HPC_SET``."""
    return _hpc_builds(HPC_SET)


#: reduced shapes for the TABLE 8 wall-clock rows: execution backends run
#: the numerics for real (interpret-mode Pallas on CPU in CI), so the
#: measured table must stay cheap while still streaming multiple row tiles
#: — and, for cg, enough iterations (≥4) that the scan-rolled path has two
#: provably identical middle iterations to roll
HPC_EXEC_SET = [
    ("cg", "cg", dict(n=1024, iters=4)),
    ("bicgstab", "bicgstab", dict(n=1024, iters=2)),
    ("gmres", "gmres", dict(n=1024, restart=4)),
    ("jacobi2d", "jacobi2d", dict(n=256, sweeps=4)),
    ("power_iteration", "power_iteration", dict(n=1024, iters=4)),
    ("mttkrp", "mttkrp", dict(i=64, j=64, k=64, rank=16)),
    ("cg_sparse/lap5", "cg_sparse", dict(n=1024, iters=4)),
    ("bicgstab_sparse/band16", "bicgstab_sparse",
     dict(n=1024, iters=2, pattern="banded", bandwidth=16)),
    ("jacobi_sparse/lap5", "jacobi_sparse", dict(n=1024, sweeps=4)),
]


def hpc_exec_workloads():
    """``(name, build)`` pairs over ``HPC_EXEC_SET`` (TABLE 8)."""
    return _hpc_builds(HPC_EXEC_SET)


def _hpc_builds(triples):
    out = []
    for label, wl, params in triples:
        sess = Session()
        out.append((f"hpc/{label}",
                    lambda s=sess, w=wl, p=params: s.trace(workload=w, **p)))
    return out


def workload_density(program) -> float:
    """Sparse operand density of a frontend program: stored entries over
    the dense silhouette of its spmv operands (1.0 for dense DAGs)."""
    ds = [nd.param("nnz") / (nd.param("rows") * nd.param("cols"))
          for nd in program.nodes.values() if nd.op == "spmv"]
    return min(ds) if ds else 1.0
