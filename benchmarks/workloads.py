"""Shared benchmark workload set: representative (arch × shape) traces
for the CELLO analysis tables (speedup / energy / capacity / split).

Each entry is ``(name, build)`` where ``build()`` returns a
``repro.api.TracedGraph``; benches run ``.codesign(...)`` on it, which hits
the shared disk cache on repeated runs.
"""
from __future__ import annotations

from repro.api import Session


def workloads():
    out = []
    for arch, batch, seq in [
        ("granite-3-8b", 4, 4096),
        ("gemma-7b", 4, 4096),
        ("minitron-8b", 4, 4096),
        ("h2o-danube-1.8b", 4, 4096),
        ("llama-3.2-vision-11b", 4, 4096),
        ("hubert-xlarge", 8, 4096),
        ("recurrentgemma-2b", 4, 4096),
        ("rwkv6-7b", 4, 4096),
        ("moonshot-v1-16b-a3b", 4, 4096),
        ("granite-moe-1b-a400m", 4, 4096),
    ]:
        sess = Session(arch)
        kinds = sess.cfg.layer_kinds()
        kind = "xattn" if "xattn" in kinds else kinds[0]
        out.append((f"{arch}/train4k",
                    lambda s=sess, b=batch, q=seq, k=kind:
                    s.trace(phase="train", batch=b, seq=q, layer_kind=k)))
    for arch in ("granite-3-8b", "gemma-7b"):
        sess = Session(arch)
        out.append((f"{arch}/prefill32k",
                    lambda s=sess: s.trace(phase="prefill", batch=1,
                                           seq=32768)))
        out.append((f"{arch}/decode32k",
                    lambda s=sess: s.trace(phase="decode", batch=128,
                                           kv_len=32768)))
    return out


#: paper-style HPC DAGs (frontend traces) for the TABLE 7 bench, as
#: ``(label, workload, params)``: skewed (n×n)·(n,) operators sized so the
#: fp64 dense operator is at/near the 128 MiB on-chip capacity — where the
#: implicit-only baseline thrashes and the co-designed explicit pin
#: captures the cross-iteration reuse.  The ``*_sparse`` rows are the
#: paper's true sparse operating points (5-point Laplacian ≈ 0.12%,
#: random 0.1% / 1%, banded): the operand's *nnz footprint* — not its
#: dense n² silhouette — is what competes for capacity, so the
#: pin-vs-stream crossover moves.
HPC_SET = [
    ("cg", "cg", dict(n=4096, iters=4)),
    ("bicgstab", "bicgstab", dict(n=4096, iters=3)),
    ("gmres", "gmres", dict(n=4096, restart=8)),
    ("jacobi2d", "jacobi2d", dict(n=4096, sweeps=8)),
    ("power_iteration", "power_iteration", dict(n=4096, iters=8)),
    ("mttkrp", "mttkrp", dict(i=256, j=256, k=256, rank=64)),
    ("cg_sparse/lap5", "cg_sparse", dict(n=4096, iters=4)),
    ("cg_sparse/d0.001", "cg_sparse",
     dict(n=4096, iters=4, pattern="random", density=0.001)),
    ("cg_sparse/d0.01", "cg_sparse",
     dict(n=4096, iters=4, pattern="random", density=0.01)),
    ("cg_sparse/band64", "cg_sparse",
     dict(n=4096, iters=4, pattern="banded", bandwidth=64)),
    ("bicgstab_sparse/d0.01", "bicgstab_sparse",
     dict(n=4096, iters=3, pattern="random", density=0.01)),
    ("jacobi_sparse/lap5", "jacobi_sparse", dict(n=4096, sweeps=8)),
]


def hpc_workloads():
    """``(name, build)`` pairs like :func:`workloads`, over ``HPC_SET``."""
    return _hpc_builds(HPC_SET)


#: reduced shapes for the TABLE 8 wall-clock rows: execution backends run
#: the numerics for real (interpret-mode Pallas on CPU in CI), so the
#: measured table must stay cheap while still streaming multiple row tiles
#: — and, for cg, enough iterations (≥4) that the scan-rolled path has two
#: provably identical middle iterations to roll
HPC_EXEC_SET = [
    ("cg", "cg", dict(n=1024, iters=4)),
    ("bicgstab", "bicgstab", dict(n=1024, iters=2)),
    ("gmres", "gmres", dict(n=1024, restart=4)),
    ("jacobi2d", "jacobi2d", dict(n=256, sweeps=4)),
    ("power_iteration", "power_iteration", dict(n=1024, iters=4)),
    ("mttkrp", "mttkrp", dict(i=64, j=64, k=64, rank=16)),
    ("cg_sparse/lap5", "cg_sparse", dict(n=1024, iters=4)),
    ("bicgstab_sparse/band16", "bicgstab_sparse",
     dict(n=1024, iters=2, pattern="banded", bandwidth=16)),
    ("jacobi_sparse/lap5", "jacobi_sparse", dict(n=1024, sweeps=4)),
]


def hpc_exec_workloads():
    """``(name, build)`` pairs over ``HPC_EXEC_SET`` (TABLE 8)."""
    return _hpc_builds(HPC_EXEC_SET)


#: overbooked-pin crossover sweep (TABLE 7): the cg solve across the
#: density axis (dense -> lap5 -> d=0.001 -> d=0.01) at explicit
#: capacities chosen just *below* each operand's CSR footprint, where
#: all-or-nothing pinning (overbook=0) must stream the operand while
#: overbook=0.25 may pin an indptr-aligned hot row prefix.  Each point
#: runs at overbook 0 and 0.25; the gap is the recovered middle ground —
#: and a zero gap is the cost model *rejecting* overbooking because the
#: streamed tail dominates (lap5 / d=0.001, whose rows hold only 4-5
#: entries).  CSR footprints at n=4096: lap5 253 KiB, d=0.001 208 KiB,
#: d=0.01 1984 KiB.
HPC_CROSSOVER_SET = [
    ("xover/cg/c208k", "cg", dict(n=4096, iters=4), 208 << 10),
    ("xover/cg_sparse/lap5/c208k", "cg_sparse",
     dict(n=4096, iters=4), 208 << 10),
    ("xover/cg_sparse/lap5/c244k", "cg_sparse",
     dict(n=4096, iters=4), 244 << 10),
    ("xover/cg_sparse/d0.001/c176k", "cg_sparse",
     dict(n=4096, iters=4, pattern="random", density=0.001), 176 << 10),
    ("xover/cg_sparse/d0.001/c204k", "cg_sparse",
     dict(n=4096, iters=4, pattern="random", density=0.001), 204 << 10),
    ("xover/cg_sparse/d0.01/c1792k", "cg_sparse",
     dict(n=4096, iters=4, pattern="random", density=0.01), 1792 << 10),
    ("xover/cg_sparse/d0.01/c1920k", "cg_sparse",
     dict(n=4096, iters=4, pattern="random", density=0.01), 1920 << 10),
]

#: measured A/B crossover point (TABLE 8): same workload and capacity,
#: overbook 0 vs 0.25, run for real on each backend — the wall-clock gap
#: is the prefix-resident padded per-tile kernel (O(per-tile entries) per
#: grid step) vs the whole-operand masked scan (O(nnz) per step).
#: (n=2048 d=0.01: CSR 512 KiB; at 480 KiB the prefix pin keeps ~82% of
#: rows resident and measures ~2x on the interpret-mode dispatch path.)
EXEC_CROSSOVER_SET = [
    ("xover/cg_sparse/d0.01/c480k", "cg_sparse",
     dict(n=2048, iters=4, pattern="random", density=0.01), 480 << 10),
]


def _crossover_points(triples):
    out = []
    for label, wl, params, cap in triples:
        sess = Session(capacity_bytes=cap)
        for ob in (0.0, 0.25):
            out.append((f"{label}/ob{int(ob * 100)}",
                        lambda s=sess, w=wl, p=params:
                        s.trace(workload=w, **p),
                        ob))
    return out


def hpc_crossover_points():
    """``(name, build, overbook)`` triples over ``HPC_CROSSOVER_SET``."""
    return _crossover_points(HPC_CROSSOVER_SET)


def exec_crossover_points():
    """``(name, build, overbook)`` triples over ``EXEC_CROSSOVER_SET``."""
    return _crossover_points(EXEC_CROSSOVER_SET)


def _hpc_builds(triples):
    out = []
    for label, wl, params in triples:
        sess = Session()
        out.append((f"hpc/{label}",
                    lambda s=sess, w=wl, p=params: s.trace(workload=w, **p)))
    return out


def workload_density(program) -> float:
    """Sparse operand density of a frontend program: stored entries over
    the dense silhouette of its spmv operands (1.0 for dense DAGs)."""
    ds = [nd.param("nnz") / (nd.param("rows") * nd.param("cols"))
          for nd in program.nodes.values() if nd.op == "spmv"]
    return min(ds) if ds else 1.0
