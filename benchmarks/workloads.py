"""Shared benchmark workload set: representative (arch × shape) traces
for the CELLO analysis tables (speedup / energy / capacity / split).

Each entry is ``(name, build)`` where ``build()`` returns a
``repro.api.TracedGraph``; benches run ``.codesign(...)`` on it, which hits
the shared disk cache on repeated runs.
"""
from __future__ import annotations

from repro.api import Session


def workloads():
    out = []
    for arch, batch, seq in [
        ("granite-3-8b", 4, 4096),
        ("gemma-7b", 4, 4096),
        ("minitron-8b", 4, 4096),
        ("h2o-danube-1.8b", 4, 4096),
        ("llama-3.2-vision-11b", 4, 4096),
        ("hubert-xlarge", 8, 4096),
        ("recurrentgemma-2b", 4, 4096),
        ("rwkv6-7b", 4, 4096),
        ("moonshot-v1-16b-a3b", 4, 4096),
        ("granite-moe-1b-a400m", 4, 4096),
    ]:
        sess = Session(arch)
        kinds = sess.cfg.layer_kinds()
        kind = "xattn" if "xattn" in kinds else kinds[0]
        out.append((f"{arch}/train4k",
                    lambda s=sess, b=batch, q=seq, k=kind:
                    s.trace(phase="train", batch=b, seq=q, layer_kind=k)))
    for arch in ("granite-3-8b", "gemma-7b"):
        sess = Session(arch)
        out.append((f"{arch}/prefill32k",
                    lambda s=sess: s.trace(phase="prefill", batch=1,
                                           seq=32768)))
        out.append((f"{arch}/decode32k",
                    lambda s=sess: s.trace(phase="decode", batch=128,
                                           kv_len=32768)))
    return out


#: paper-style HPC DAGs (frontend traces) for the TABLE 7 bench: skewed
#: (n×n)·(n,) operators sized so the fp64 operator is at/near the 128 MiB
#: on-chip capacity — where the implicit-only baseline thrashes and the
#: co-designed explicit pin captures the cross-iteration reuse.
HPC_SET = [
    ("cg", dict(n=4096, iters=4)),
    ("bicgstab", dict(n=4096, iters=3)),
    ("gmres", dict(n=4096, restart=8)),
    ("jacobi2d", dict(n=4096, sweeps=8)),
    ("power_iteration", dict(n=4096, iters=8)),
    ("mttkrp", dict(i=256, j=256, k=256, rank=64)),
]


def hpc_workloads():
    """``(name, build)`` pairs like :func:`workloads`, over ``HPC_SET``."""
    return _hpc_builds(HPC_SET)


#: reduced shapes for the TABLE 8 wall-clock rows: execution backends run
#: the numerics for real (interpret-mode Pallas on CPU in CI), so the
#: measured table must stay cheap while still streaming multiple row tiles
#: — and, for cg, enough iterations (≥4) that the scan-rolled path has two
#: provably identical middle iterations to roll
HPC_EXEC_SET = [
    ("cg", dict(n=1024, iters=4)),
    ("bicgstab", dict(n=1024, iters=2)),
    ("gmres", dict(n=1024, restart=4)),
    ("jacobi2d", dict(n=256, sweeps=4)),
    ("power_iteration", dict(n=1024, iters=4)),
    ("mttkrp", dict(i=64, j=64, k=64, rank=16)),
]


def hpc_exec_workloads():
    """``(name, build)`` pairs over ``HPC_EXEC_SET`` (TABLE 8)."""
    return _hpc_builds(HPC_EXEC_SET)


def _hpc_builds(pairs):
    out = []
    for wl, params in pairs:
        sess = Session()
        out.append((f"hpc/{wl}",
                    lambda s=sess, w=wl, p=params: s.trace(workload=w, **p)))
    return out
