"""Table 6 — the roofline table from the dry-run artifacts: three terms per
(arch × shape × mesh), dominant bottleneck, MODEL_FLOPS ratio.  Also emits
``experiments/roofline.md`` for EXPERIMENTS.md."""
from __future__ import annotations

import glob
import json
import os
from typing import Dict, List

DRYRUN_DIR = os.path.join(os.path.dirname(__file__), "..",
                          "experiments", "dryrun")


def load_cells(tag: str = "") -> List[Dict]:
    cells = []
    for path in sorted(glob.glob(os.path.join(DRYRUN_DIR, "*.json"))):
        base = os.path.basename(path)[:-5]
        parts = base.split("__")
        cell_tag = parts[3] if len(parts) > 3 else ""
        if cell_tag != tag:
            continue
        with open(path) as f:
            cells.append(json.load(f))
    return cells


def run() -> List[str]:
    rows = ["cell,us_per_call,compute_ms,memory_ms,collective_ms,dominant,"
            "useful_flops_ratio,roofline_fraction"]
    md = ["| arch | shape | mesh | compute (ms) | memory (ms) | "
          "collective (ms) | dominant | 6ND/HLO | roofline frac |",
          "|---|---|---|---|---|---|---|---|---|"]
    for c in load_cells():
        name = f"{c['arch']}/{c['shape']}/{c['mesh']}"
        if c.get("status") == "skipped":
            # CSV cell: free-text reasons must not carry the delimiter
            reason = c["reason"][:40].replace(",", ";")
            rows.append(f"{name},0,,,,skipped({reason}),,")
            md.append(f"| {c['arch']} | {c['shape']} | {c['mesh']} | — | — "
                      f"| — | skipped | — | — |")
            continue
        if c.get("status") != "ok":
            rows.append(f"{name},0,,,,ERROR,,")
            continue
        r = c["roofline"]
        rows.append(
            f"{name},{c['compile_s'] * 1e6:.0f},"
            f"{r['compute_s'] * 1e3:.3f},{r['memory_s'] * 1e3:.3f},"
            f"{r['collective_s'] * 1e3:.3f},{r['dominant']},"
            f"{r['useful_flops_ratio']:.3f},{r['roofline_fraction']:.3f}")
        md.append(
            f"| {c['arch']} | {c['shape']} | {c['mesh']} "
            f"| {r['compute_s'] * 1e3:.2f} | {r['memory_s'] * 1e3:.2f} "
            f"| {r['collective_s'] * 1e3:.2f} | **{r['dominant']}** "
            f"| {r['useful_flops_ratio']:.2f} "
            f"| {r['roofline_fraction']:.2f} |")
    out_md = os.path.join(DRYRUN_DIR, "..", "roofline.md")
    os.makedirs(os.path.dirname(out_md), exist_ok=True)
    with open(out_md, "w") as f:
        f.write("\n".join(md) + "\n")
    return rows


if __name__ == "__main__":
    print("\n".join(run()))
