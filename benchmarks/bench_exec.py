"""Table 8 — measured wall-clock per execution backend, next to the cost
model's predicted speedups.

Every frontend workload is lowered once per backend and *executed for
real*: ``reference`` replays the co-designed order through the jax.numpy
interpreter; ``pallas`` compiles the whole plan into ONE jitted
single-program executable (residency-fused units, scan-rolled solver
iterations, exactly one device dispatch per run); ``pallas-perunit`` is
the 0.4-era per-unit driver kept as the A/B baseline the single-program
speedup is measured against.  Off-TPU the Pallas kernels run in interpret
mode, so CI exercises the actual lowering — interpret wall-clock measures
the lowering/dispatch path, not TPU kernel time.
``predicted_speedup_vs_implicit`` is the co-design model's claim for the
same schedule, reported alongside so the measured trajectory can be
tracked against it per PR (``BENCH_exec.json``).

Timing protocol: one warmup run (excluded — it pays tracing/compilation),
then the **median** of ``repeats`` timed runs (default 3; CI passes
``--repeats 5`` through ``benchmarks.run``).

``pallas_groups`` / ``jnp_groups`` count how many fusion groups lowered to
real Pallas kernels vs the jitted jax.numpy fallback; ``exec_units`` is
the fused dispatch-unit count and ``rolled_iters`` the rolled iteration
trip count (0 = straight-line); ``max_rel_err_vs_reference`` is the
observed parity gap (the documented tolerance is rtol=2e-4 for float32
reduction reassociation).
"""
from __future__ import annotations

import statistics
import time
from typing import List, Optional

import numpy as np

from repro.api import CodesignConfig

REPS = 3

#: backends measured by default — the per-unit driver rides along so every
#: BENCH_exec.json records the single-program speedup on the same machine
BACKENDS = ("reference", "pallas", "pallas-perunit")


def _rel_err(got, want) -> float:
    g, w = np.asarray(got, np.float64), np.asarray(want, np.float64)
    denom = np.maximum(np.abs(w), 1e-6)
    return float(np.max(np.abs(g - w) / denom))


def run(backend: Optional[str] = None,
        repeats: Optional[int] = None) -> List[str]:
    import jax

    from repro.frontends import make_feeds

    from .workloads import (exec_crossover_points, hpc_exec_workloads,
                            workload_density)

    reps = int(repeats) if repeats else REPS
    backends = [backend] if backend else list(BACKENDS)
    rows = ["workload,us_per_call,backend,predicted_speedup_vs_implicit,"
            "groups,pallas_groups,jnp_groups,exec_units,rolled_iters,"
            "max_rel_err_vs_reference,density,capacity_kib,overbook"]
    points = [(name, build, 0.0) for name, build in hpc_exec_workloads()]
    points += exec_crossover_points()
    for name, build, overbook in points:
        # the crossover A/B rows compare overbook=0 vs 0.25 wall-clock at
        # one capacity; reference rides along as the normalizer, but the
        # per-unit driver adds nothing to that comparison
        xover = name.startswith("xover/")
        bes = [be for be in backends
               if not (xover and be == "pallas-perunit")]
        traced = build()
        designed = traced.codesign(CodesignConfig(overbook=overbook))
        feeds = make_feeds(traced.program, seed=0)
        baseline = None
        if any(be != "reference" for be in bes):
            # parity column needs the oracle, whatever backend is measured
            baseline = designed.lower(backend="reference").run(feeds)
        for be in bes:
            plan = designed.lower(backend=be)
            out = jax.block_until_ready(plan.run(feeds))   # warmup: traces
            times = []
            for _ in range(reps):
                t0 = time.perf_counter()
                out = jax.block_until_ready(plan.run(feeds))
                times.append(time.perf_counter() - t0)
            med = statistics.median(times)
            kinds = [gk.kind for gk in plan.group_kernels]
            ep = plan.exec_plan
            units = len(ep.units) if ep is not None else 0
            rolled = (ep.roll.n_iters
                      if ep is not None and ep.roll is not None else 0)
            err = 0.0
            if be != "reference" and baseline is not None:
                err = max(_rel_err(out[k], baseline[k]) for k in baseline)
            rows.append(
                f"{name}[{be}],{med * 1e6:.0f},{be},"
                f"{designed.speedup():.3f},{len(kinds)},"
                f"{sum(k != 'jnp' for k in kinds)},"
                f"{sum(k == 'jnp' for k in kinds)},"
                f"{units},{rolled},{err:.2e},"
                f"{workload_density(traced.program):.6f},"
                f"{traced.session.capacity_bytes >> 10},{overbook}")
    return rows


if __name__ == "__main__":
    print("\n".join(run()))
