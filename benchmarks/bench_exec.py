"""Table 8 — measured wall-clock per execution backend, next to the cost
model's predicted speedups.

Every frontend workload is lowered once per backend and *executed for real*:
``reference`` replays the co-designed order through the jax.numpy
interpreter; ``pallas`` compiles each fusion group into tile-streaming
``pl.pallas_call`` kernels (interpret mode off-TPU, so CI exercises the
actual lowering — interpret wall-clock measures the lowering/dispatch path,
not TPU kernel time).  ``predicted_speedup_vs_implicit`` is the co-design
model's claim for the same schedule, reported alongside so the measured
trajectory can be tracked against it per PR (``BENCH_exec.json``).

``pallas_groups`` / ``jnp_groups`` count how many fusion groups lowered to
real Pallas kernels vs the jitted jax.numpy fallback;
``max_rel_err_vs_reference`` is the observed parity gap (the documented
tolerance is rtol=2e-4 for float32 reduction reassociation).
"""
from __future__ import annotations

import time
from typing import List, Optional

import numpy as np

REPS = 3


def _rel_err(got, want) -> float:
    g, w = np.asarray(got, np.float64), np.asarray(want, np.float64)
    denom = np.maximum(np.abs(w), 1e-6)
    return float(np.max(np.abs(g - w) / denom))


def run(backend: Optional[str] = None) -> List[str]:
    import jax

    from repro.frontends import make_feeds

    from .workloads import hpc_exec_workloads

    backends = [backend] if backend else ["reference", "pallas"]
    rows = ["workload,us_per_call,backend,predicted_speedup_vs_implicit,"
            "groups,pallas_groups,jnp_groups,max_rel_err_vs_reference"]
    for name, build in hpc_exec_workloads():
        traced = build()
        designed = traced.codesign()
        feeds = make_feeds(traced.program, seed=0)
        baseline = None
        if any(be != "reference" for be in backends):
            # parity column needs the oracle, whatever backend is measured
            baseline = designed.lower(backend="reference").run(feeds)
        for be in backends:
            plan = designed.lower(backend=be)
            out = jax.block_until_ready(plan.run(feeds))     # warm compile
            best = float("inf")
            for _ in range(REPS):
                t0 = time.perf_counter()
                out = jax.block_until_ready(plan.run(feeds))
                best = min(best, time.perf_counter() - t0)
            kinds = [gk.kind for gk in plan.group_kernels]
            err = 0.0
            if be != "reference" and baseline is not None:
                err = max(_rel_err(out[k], baseline[k]) for k in baseline)
            rows.append(
                f"{name}[{be}],{best * 1e6:.0f},{be},"
                f"{designed.speedup():.3f},{len(kinds)},"
                f"{sum(k != 'jnp' for k in kinds)},"
                f"{sum(k == 'jnp' for k in kinds)},{err:.2e}")
    return rows


if __name__ == "__main__":
    print("\n".join(run()))
