"""Table 10 — serving under sustained overload, per admission policy.

Thin registry shim: the implementation lives next to the rest of the
serving bench in :mod:`benchmarks.bench_serve` (``run_overload``), which
shares its router warmup and shape constants.  The scenario pins dispatch
time with the fault-injection harness so ``served_frac`` is deterministic
by construction — see that docstring for the row semantics and the gate
(``served_frac:higher`` in the robustness-smoke CI job).
"""
from __future__ import annotations

from typing import List, Optional

from . import bench_serve


def run(backend: Optional[str] = None) -> List[str]:
    return bench_serve.run_overload(backend=backend)


if __name__ == "__main__":
    print("\n".join(run()))
