"""Table 2 — energy (J) and energy ratio vs the implicit-only baseline;
the paper's second evaluation metric."""
from __future__ import annotations

import time
from typing import List

from repro.core import V5E

from .workloads import workloads


def run() -> List[str]:
    rows = ["workload,us_per_call,cached,energy_mj_cello,energy_mj_implicit,"
            "energy_ratio,hbm_energy_frac"]
    for name, build in workloads():
        traced = build()
        t0 = time.perf_counter()
        res = traced.codesign()
        us = (time.perf_counter() - t0) * 1e6
        e_c = res.best.metrics.energy_j * 1e3
        e_i = res.baselines["seq-implicit"].metrics.energy_j * 1e3
        # fraction of CELLO energy still spent on HBM traffic
        hbm_j = res.best.metrics.hbm_bytes * V5E.e_hbm_byte * 1e3
        rows.append(f"{name},{us:.0f},{int(res.from_cache)},"
                    f"{e_c:.3f},{e_i:.3f},"
                    f"{e_i / e_c:.3f},{hbm_j / e_c:.3f}")
    return rows


if __name__ == "__main__":
    print("\n".join(run()))
