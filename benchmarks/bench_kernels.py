"""Table 5 — kernel microbench: fused Pallas path (interpret on CPU) vs the
pure-jnp oracle; reports wall time per call and max |err| (the correctness
column; wall time on CPU-interpret is NOT a TPU projection)."""
from __future__ import annotations

import time
from typing import List

import numpy as np
import jax.numpy as jnp


def _t(fn, *args, reps=3):
    fn(*args)                                        # compile / warm
    t0 = time.perf_counter()
    for _ in range(reps):
        out = fn(*args)
    jax_block = getattr(out, "block_until_ready", None)
    if jax_block:
        jax_block()
    elif isinstance(out, tuple):
        out[0].block_until_ready()
    return (time.perf_counter() - t0) / reps * 1e6, out


def run() -> List[str]:
    rng = np.random.default_rng(0)
    rows = ["kernel,us_per_call,max_abs_err"]

    from repro.kernels.flash_attention import flash_attention, mha_reference
    q = jnp.asarray(rng.standard_normal((1, 4, 256, 64)), jnp.float32)
    k = jnp.asarray(rng.standard_normal((1, 2, 256, 64)), jnp.float32)
    v = jnp.asarray(rng.standard_normal((1, 2, 256, 64)), jnp.float32)
    us, out = _t(lambda *a: flash_attention(*a, causal=True, q_block=128,
                                            kv_block=128), q, k, v)
    err = np.abs(np.asarray(out)
                 - np.asarray(mha_reference(q, k, v, causal=True))).max()
    rows.append(f"flash_attention,{us:.0f},{err:.2e}")

    from repro.kernels.fused_mlp import fused_mlp, mlp_reference
    x = jnp.asarray(rng.standard_normal((256, 128)) * .5, jnp.float32)
    wg = jnp.asarray(rng.standard_normal((128, 256)) * .1, jnp.float32)
    wu = jnp.asarray(rng.standard_normal((128, 256)) * .1, jnp.float32)
    wd = jnp.asarray(rng.standard_normal((256, 128)) * .1, jnp.float32)
    us, out = _t(lambda *a: fused_mlp(*a, activation="silu", m_block=128,
                                      f_block=128), x, wg, wu, wd)
    err = np.abs(np.asarray(out)
                 - np.asarray(mlp_reference(x, wg, wu, wd))).max()
    rows.append(f"fused_mlp,{us:.0f},{err:.2e}")

    from repro.kernels.rglru import rglru, rglru_reference
    xs = jnp.asarray(rng.standard_normal((2, 64, 128)), jnp.float32)
    gr = jnp.asarray(rng.standard_normal((2, 64, 128)), jnp.float32)
    gi = jnp.asarray(rng.standard_normal((2, 64, 128)), jnp.float32)
    ap = jnp.asarray(rng.standard_normal(128), jnp.float32)
    us, out = _t(lambda *a: rglru(*a, d_block=128), xs, gr, gi, ap)
    err = np.abs(np.asarray(out[0])
                 - np.asarray(rglru_reference(xs, gr, gi, ap)[0])).max()
    rows.append(f"rglru,{us:.0f},{err:.2e}")

    from repro.kernels.rwkv6 import wkv6, wkv6_reference
    r = jnp.asarray(rng.standard_normal((1, 2, 32, 64)), jnp.float32)
    kk = jnp.asarray(rng.standard_normal((1, 2, 32, 64)) * .3, jnp.float32)
    vv = jnp.asarray(rng.standard_normal((1, 2, 32, 64)), jnp.float32)
    w = jnp.asarray(rng.standard_normal((1, 2, 32, 64)) * .5, jnp.float32)
    u = jnp.asarray(rng.standard_normal((2, 64)) * .3, jnp.float32)
    us, out = _t(wkv6, r, kk, vv, w, u)
    err = np.abs(np.asarray(out[0])
                 - np.asarray(wkv6_reference(r, kk, vv, w, u)[0])).max()
    rows.append(f"wkv6,{us:.0f},{err:.2e}")

    from repro.kernels.rmsnorm import rmsnorm, rmsnorm_reference
    x2 = jnp.asarray(rng.standard_normal((512, 256)), jnp.float32)
    w2 = jnp.asarray(rng.standard_normal(256) * .1, jnp.float32)
    us, out = _t(lambda *a: rmsnorm(*a, row_block=128), x2, w2)
    err = np.abs(np.asarray(out) - np.asarray(rmsnorm_reference(x2, w2))).max()
    rows.append(f"rmsnorm,{us:.0f},{err:.2e}")
    return rows


if __name__ == "__main__":
    print("\n".join(run()))
