"""Table 1 — CELLO speedup vs implicit-only / explicit-only / fused-only
baselines (the paper's headline metric), per workload."""
from __future__ import annotations

import time
from typing import List

from .workloads import workloads


def run() -> List[str]:
    rows = ["workload,us_per_call,cached,speedup_vs_implicit,"
            "speedup_vs_explicit,speedup_vs_fused,hbm_reduction"]
    for name, build in workloads():
        traced = build()
        t0 = time.perf_counter()
        res = traced.codesign()
        us = (time.perf_counter() - t0) * 1e6
        m = res.best.metrics
        si = res.speedup("seq-implicit")
        se = res.baselines["seq-explicit"].metrics.time_s / m.time_s
        sf = res.baselines["fused-only"].metrics.time_s / m.time_s
        hbm = (res.baselines["seq-implicit"].metrics.hbm_bytes
               / max(1, m.hbm_bytes))
        rows.append(f"{name},{us:.0f},{int(res.from_cache)},"
                    f"{si:.3f},{se:.3f},{sf:.3f},{hbm:.2f}")
    return rows


if __name__ == "__main__":
    print("\n".join(run()))
