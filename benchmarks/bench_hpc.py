"""Table 7 — HPC DAG speedup vs implicit-only / explicit-only / fused-only
baselines: the paper's headline workload class (Krylov solvers and tensor
kernels with skewed-shape operators and cross-iteration reuse), entered
through the ``repro.frontends`` expression DAGs.

``speedup_vs_fused_nopin`` isolates the pinning contribution: the baseline
fuses greedily at full explicit capacity but may not pin, so the gap is
exactly the cross-iteration reuse a pure schedule cannot capture.
(The standard ``fused-only`` baseline fuses *and* pins — a point inside
the search space, so CELLO vs it is ~1.0 by construction.)  ``pinned``
lists the winning schedule's explicit-region pins ('+'-joined to stay
CSV-safe) — for the solvers this is the operator ``A`` plus
residual/direction vectors.  ``density`` records the sparse operand's
stored-entry fraction (1.0 for dense rows); for the ``*_sparse`` rows the
pinned set is the operand's CSR triple — pinned by *nnz footprint*, the
density-aware decision the dense rows can't make.

``--backend NAME`` (via ``benchmarks.run``) appends measured execution
columns: the plan is lowered for that backend and run at the paper shapes
(one excluded warmup, then the median of ``--repeats`` runs), adding
``backend`` and ``run_us`` wall-clock next to the model columns — the
model's claims and the executed schedule in one table.
"""
from __future__ import annotations

import statistics
import time
from typing import List, Optional

from repro.api import CodesignConfig
from repro.core.search import SearchContext, evaluate_point

from .workloads import (hpc_crossover_points, hpc_workloads,
                        workload_density)


def run(backend: Optional[str] = None,
        repeats: Optional[int] = None) -> List[str]:
    reps = int(repeats) if repeats else 1
    rows = ["workload,us_per_call,cached,best_split,speedup_vs_implicit,"
            "speedup_vs_explicit,speedup_vs_fused_nopin,hbm_reduction,"
            "density,capacity_kib,overbook,pinned"
            + (",backend,run_us" if backend else "")]
    points = [(name, build, 0.0) for name, build in hpc_workloads()]
    points += hpc_crossover_points()
    for name, build, overbook in points:
        traced = build()
        t0 = time.perf_counter()
        res = traced.codesign(CodesignConfig(overbook=overbook))
        us = (time.perf_counter() - t0) * 1e6
        m = res.best.metrics
        si = res.speedup("seq-implicit")
        se = res.baselines["seq-explicit"].metrics.time_s / m.time_s
        ctx = SearchContext(graph=traced.graph,
                            hw=traced.session.hw,
                            capacity_bytes=traced.session.capacity_bytes)
        nopin = evaluate_point(ctx, traced.graph.topo_order(), 1.0,
                               fuse=True, pin=False)
        sf = nopin.metrics.time_s / m.time_s
        hbm = (res.baselines["seq-implicit"].metrics.hbm_bytes
               / max(1, m.hbm_bytes))
        pins = res.best.schedule.pins
        partial = dict(getattr(pins, "partial", None) or {})
        # prefix-pinned members render with their resident fraction,
        # so the crossover rows say *how much* of the operand pinned
        pinned = "+".join(
            f"{t}({partial[t].frac:.2f})" if t in partial else t
            for t in sorted(pins)) if pins else "(none)"
        density = workload_density(traced.program)
        row = (f"{name},{us:.0f},{int(res.from_cache)},"
               f"{res.best.schedule.config.explicit_frac},"
               f"{si:.3f},{se:.3f},{sf:.3f},{hbm:.2f},"
               f"{density:.6f},"
               f"{traced.session.capacity_bytes >> 10},"
               f"{overbook},{pinned}")
        if backend:
            import jax

            from repro.frontends import make_feeds
            plan = res.lower(backend=backend)
            feeds = make_feeds(traced.program, seed=0)
            jax.block_until_ready(plan.run(feeds))      # warmup: traces
            times = []
            for _ in range(reps):
                t0 = time.perf_counter()
                jax.block_until_ready(plan.run(feeds))
                times.append(time.perf_counter() - t0)
            row += (f",{backend},"
                    f"{statistics.median(times) * 1e6:.0f}")
        rows.append(row)
    return rows


if __name__ == "__main__":
    print("\n".join(run()))
