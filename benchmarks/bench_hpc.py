"""Table 7 — HPC DAG speedup vs implicit-only / explicit-only / fused-only
baselines: the paper's headline workload class (Krylov solvers and tensor
kernels with skewed-shape operators and cross-iteration reuse), entered
through the ``repro.frontends`` expression DAGs.

``speedup_vs_fused_nopin`` isolates the pinning contribution: the baseline
fuses greedily at full explicit capacity but may not pin, so the gap is
exactly the cross-iteration reuse a pure schedule cannot capture.
(The standard ``fused-only`` baseline fuses *and* pins — a point inside
the search space, so CELLO vs it is ~1.0 by construction.)  ``pinned``
lists the winning schedule's explicit-region pins ('+'-joined to stay
CSV-safe) — for the solvers this is the operator ``A`` plus
residual/direction vectors.
"""
from __future__ import annotations

import time
from typing import List

from repro.core.search import SearchContext, evaluate_point

from .workloads import hpc_workloads


def run() -> List[str]:
    rows = ["workload,us_per_call,cached,best_split,speedup_vs_implicit,"
            "speedup_vs_explicit,speedup_vs_fused_nopin,hbm_reduction,"
            "pinned"]
    for name, build in hpc_workloads():
        traced = build()
        t0 = time.perf_counter()
        res = traced.codesign()
        us = (time.perf_counter() - t0) * 1e6
        m = res.best.metrics
        si = res.speedup("seq-implicit")
        se = res.baselines["seq-explicit"].metrics.time_s / m.time_s
        ctx = SearchContext(graph=traced.graph,
                            hw=traced.session.hw,
                            capacity_bytes=traced.session.capacity_bytes)
        nopin = evaluate_point(ctx, traced.graph.topo_order(), 1.0,
                               fuse=True, pin=False)
        sf = nopin.metrics.time_s / m.time_s
        hbm = (res.baselines["seq-implicit"].metrics.hbm_bytes
               / max(1, m.hbm_bytes))
        pins = res.best.schedule.pins
        pinned = "+".join(sorted(pins)) if pins else "(none)"
        rows.append(f"{name},{us:.0f},{int(res.from_cache)},"
                    f"{res.best.schedule.config.explicit_frac},"
                    f"{si:.3f},{se:.3f},{sf:.3f},{hbm:.2f},{pinned}")
    return rows


if __name__ == "__main__":
    print("\n".join(run()))
