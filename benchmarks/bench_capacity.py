"""Table 3 — HBM traffic vs on-chip buffer capacity sweep (the buffer-size
sensitivity study every buffer paper reports)."""
from __future__ import annotations

import time
from typing import List

from repro.api import CodesignConfig
from repro.core.buffer import MiB

from .workloads import workloads

CAPACITIES = [16 * MiB, 32 * MiB, 64 * MiB, 128 * MiB, 256 * MiB]
SUBSET = ("granite-3-8b/train4k", "granite-3-8b/prefill32k",
          "moonshot-v1-16b-a3b/train4k", "rwkv6-7b/train4k",
          "granite-3-8b/decode32k")


def run() -> List[str]:
    rows = ["workload,us_per_call,cache_hits," +
            ",".join(f"hbm_mb@{c // MiB}MiB" for c in CAPACITIES)]
    for name, build in workloads():
        if name not in SUBSET:
            continue
        traced = build()
        t0 = time.perf_counter()
        cells, hits = [], 0
        for cap in CAPACITIES:
            res = traced.codesign(CodesignConfig(capacity_bytes=cap))
            hits += int(res.from_cache)
            cells.append(f"{res.best.metrics.hbm_bytes / 1e6:.1f}")
        us = (time.perf_counter() - t0) * 1e6
        # per-call hit count (0..len(CAPACITIES)): a partially-warm row
        # (e.g. after adding one capacity) is distinguishable from a cold one
        rows.append(f"{name},{us:.0f},{hits}," + ",".join(cells))
    return rows


if __name__ == "__main__":
    print("\n".join(run()))
